"""Step-atomic checkpoint manager (fault-tolerance substrate).

Layout: <dir>/step_<N>/ {arrays.npz (flattened pytree), index.json
(treedef + shapes + dtypes + step + digest)} written to a tmp dir and
atomically renamed — a crash mid-write never corrupts the latest
checkpoint.  Async mode hands the (host-fetched) state to a writer
thread so the train loop never blocks on disk.  keep_n old steps are
garbage-collected.  ``restore`` loads the newest complete step;
``restore_resharded`` re-places arrays onto a *different* mesh
(elastic scaling: checkpoints are mesh-agnostic by construction since
we store full logical arrays).
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import ml_dtypes
import numpy as np

# numpy can neither savez nor astype bf16 natively — round-trip via uint16
_EXOTIC = {"bfloat16": ml_dtypes.bfloat16}


def _encode(a: np.ndarray) -> np.ndarray:
    if str(a.dtype) in _EXOTIC:
        return a.view(np.uint16)
    return a


def _decode(a: np.ndarray, dtype_str: str) -> np.ndarray:
    if dtype_str in _EXOTIC:
        return a.view(_EXOTIC[dtype_str])
    return a


def _flatten(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def _digest(arrays) -> str:
    h = hashlib.sha256()
    for a in arrays:
        h.update(np.ascontiguousarray(a).tobytes()[:4096])
    return h.hexdigest()[:16]


class CheckpointManager:
    def __init__(self, directory: str, keep_n: int = 3,
                 async_write: bool = True):
        self.dir = directory
        self.keep_n = keep_n
        self.async_write = async_write
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------ save

    def save(self, step: int, state: Any, block: bool = False):
        leaves, treedef = _flatten(state)
        host = [np.asarray(jax.device_get(x)) for x in leaves]
        if self.async_write and not block:
            self.wait()
            self._thread = threading.Thread(
                target=self._write, args=(step, host, str(treedef)),
                daemon=True)
            self._thread.start()
        else:
            self._write(step, host, str(treedef))

    def _write(self, step: int, host, treedef_str: str):
        final = os.path.join(self.dir, f"step_{step:08d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        np.savez(os.path.join(tmp, "arrays.npz"),
                 **{f"a{i}": _encode(a) for i, a in enumerate(host)})
        index = {
            "step": step,
            "n_arrays": len(host),
            "treedef": treedef_str,
            "shapes": [list(a.shape) for a in host],
            "dtypes": [str(a.dtype) for a in host],
            "digest": _digest(host),
        }
        with open(os.path.join(tmp, "index.json"), "w") as f:
            json.dump(index, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)  # atomic commit
        self._gc()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep_n]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    # ------------------------------------------------------------ load

    def all_steps(self):
        out = []
        for d in sorted(os.listdir(self.dir)):
            if d.startswith("step_") and not d.endswith(".tmp") \
                    and os.path.exists(os.path.join(self.dir, d,
                                                    "index.json")):
                out.append(int(d.split("_")[1]))
        return out

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, like: Any, step: Optional[int] = None):
        """Restore into the structure of `like` (validates the index).
        Returns (state, step) or (None, None) if no checkpoint."""
        step = step if step is not None else self.latest_step()
        if step is None:
            return None, None
        d = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(d, "index.json")) as f:
            index = json.load(f)
        data = np.load(os.path.join(d, "arrays.npz"))
        arrays = [_decode(data[f"a{i}"], index["dtypes"][i])
                  for i in range(index["n_arrays"])]
        if _digest(arrays) != index["digest"]:
            raise IOError(f"checkpoint step {step} digest mismatch")
        leaves, treedef = _flatten(like)
        assert len(leaves) == len(arrays), "structure mismatch"
        out = []
        for ref, a in zip(leaves, arrays):
            assert tuple(ref.shape) == tuple(a.shape), (ref.shape, a.shape)
            out.append(a if str(a.dtype) == str(ref.dtype)
                       else a.astype(ref.dtype))
        return jax.tree.unflatten(treedef, out), step

    def restore_resharded(self, like_specs: Any, step: Optional[int] = None):
        """Elastic restore: place arrays per ShapeDtypeStruct+sharding specs
        of a NEW mesh (possibly different size than at save time)."""
        state, step = self.restore(like_specs, step)
        if state is None:
            return None, None
        placed = jax.tree.map(
            lambda a, s: jax.device_put(a, s.sharding)
            if getattr(s, "sharding", None) is not None else jax.device_put(a),
            state, like_specs)
        return placed, step
