"""Optional jax.profiler passthrough + device-memory events.

The span trace answers "where did the wall time go" at pipeline
granularity; for kernel-level truth on the TPU phase-2 runs you want
jax's own profiler.  :func:`maybe_profile` wraps a block in
``jax.profiler.trace(logdir)`` when a log dir is configured
(``REPRO_OBS_JAXPROF`` or an explicit argument) and is a no-op
otherwise — the sweep pipeline calls it unconditionally.
:func:`device_memory_event` snapshots ``Device.memory_stats()`` into an
``device_memory`` trace event where the backend exposes it (TPU/GPU;
CPU returns None and emits nothing).

jax imports are deferred so ``repro.obs`` stays importable — and its
CLI usable on raw JSONL files — without initializing jax.
"""
from __future__ import annotations

import os
from contextlib import contextmanager


def profiler_logdir(logdir: str | None = None) -> str | None:
    return logdir or os.environ.get("REPRO_OBS_JAXPROF", "").strip() or None


@contextmanager
def maybe_profile(logdir: str | None = None):
    """``jax.profiler.trace(logdir)`` when configured, else a no-op."""
    logdir = profiler_logdir(logdir)
    if not logdir:
        yield None
        return
    import jax

    with jax.profiler.trace(logdir):
        yield logdir


def device_memory_event(emit, parent=None):
    """Emit one ``device_memory`` event via `emit` (an ``obs.event``-shaped
    callable) with per-device ``memory_stats()``; returns the stats dict
    or None when no device reports any (CPU backend)."""
    import jax

    stats = {}
    for d in jax.local_devices():
        try:
            s = d.memory_stats()
        except Exception:
            s = None
        if s:
            stats[str(d.id)] = {k: int(v) for k, v in s.items()
                                if isinstance(v, (int, float))}
    if not stats:
        return None
    from repro.obs import names

    emit(names.EV_DEVICE_MEMORY, parent=parent, devices=stats)
    return stats
