"""CLI: rollup / verify / diff obs traces and BENCH_sweep artifacts.

    PYTHONPATH=src python -m repro.obs report TRACE.jsonl
    PYTHONPATH=src python -m repro.obs report TRACE.jsonl --check BENCH.json
    PYTHONPATH=src python -m repro.obs diff OLD.json NEW.json [--warn-pct 20]

``report`` prints the trace rollup (derived fill records, span totals,
counters); ``--check`` re-derives every ladder-fill record from the raw
JSONL and compares it field-by-field against the artifact's
``ladder_fills`` (exit 1 on any mismatch — the artifact is then NOT a
faithful readout of the run).  ``diff`` compares two artifacts'
wall-time fields and reports regressions over the threshold; it exits 0
(warn-only) unless ``--fail`` is given — CI uses warn-only so noisy
container timings cannot block a merge.
"""
from __future__ import annotations

import argparse
import json
import sys

from repro.obs import report


def _load_json(path: str) -> dict:
    with open(path, encoding="utf-8") as f:
        return json.load(f)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.obs")
    sub = ap.add_subparsers(dest="cmd", required=True)

    p_rep = sub.add_parser("report", help="rollup a JSONL trace")
    p_rep.add_argument("trace", help="path to a trace .jsonl")
    p_rep.add_argument("--check", metavar="BENCH_JSON", default=None,
                       help="verify this BENCH_sweep artifact against "
                            "the trace (exit 1 on mismatch)")
    p_rep.add_argument("--json", action="store_true",
                       help="print the rollup as JSON")

    p_diff = sub.add_parser("diff", help="compare two BENCH artifacts")
    p_diff.add_argument("old")
    p_diff.add_argument("new")
    p_diff.add_argument("--warn-pct", type=float, default=20.0,
                        help="wall-time regression threshold (default 20)")
    p_diff.add_argument("--fail", action="store_true",
                        help="exit 1 when regressions exceed the threshold")

    args = ap.parse_args(argv)

    if args.cmd == "report":
        events = report.read_trace(args.trace)
        roll = report.rollup(events, trace_file=args.trace)
        if args.json:
            print(json.dumps(roll, indent=2, sort_keys=True))
        else:
            print(f"trace: {args.trace}  ({roll['n_events']} events)")
            for i, rec in enumerate(roll["fills"]):
                print(f"fill[{i}]: " + json.dumps(rec, sort_keys=True))
            for name, t in sorted(roll["spans"].items()):
                print(f"span {name:<18} n={t['count']:<5} "
                      f"dur_s={t['dur_s']}")
            for name, c in sorted(roll["events"].items()):
                print(f"event {name:<17} n={c}")
            for name, c in sorted(roll["counters"].items()):
                print(f"counter {name:<15} n={c}")
        if args.check:
            problems = report.check(events, _load_json(args.check),
                                    trace_file=args.trace)
            if problems:
                for p in problems:
                    print(f"CHECK FAIL: {p}", file=sys.stderr)
                return 1
            print(f"check OK: {args.check} matches the trace "
                  f"({len(roll['fills'])} fills, bit-exact)")
        return 0

    if args.cmd == "diff":
        res = report.diff(_load_json(args.old), _load_json(args.new),
                          warn_pct=args.warn_pct)
        print(json.dumps({k: res[k] for k in ("fills", "old_only")},
                         indent=2))
        for r in res["regressions"]:
            print(f"WARNING: wall-time regression: {r}", file=sys.stderr)
        if res["regressions"] and args.fail:
            return 1
        return 0

    return 2  # pragma: no cover - argparse enforces a subcommand


if __name__ == "__main__":
    sys.exit(main())
