"""Derive BENCH_sweep fill records from span traces — live and offline.

The contract that makes ``BENCH_sweep.json`` trustworthy: the producer
(``sim.runner.run_ladder``) does NOT hand-assemble its ``LADDER_PERF``
record.  It closes the fill's span tree and calls :func:`fill_record`
on the tracer's in-memory events — the SAME function the CLI
(``python -m repro.obs report``) applies to the JSONL file.  Because
span records are JSON-sanitized at emission (``tracer._jsonable``) and
events are replayed in emission order, the offline reconstruction is
**bit-exact**, which ``report --check`` (and the round-trip test)
asserts against a written artifact.

:data:`FIELD_SOURCES` is the field→source table the derivation walks;
the OB001 analyzer pass (``repro.analysis.obs_contract``) checks it
stays closed over :data:`SCHEMA6_FIELDS` and only references declared
names — no orphan hand-set fields can reappear.
"""
from __future__ import annotations

import json

from repro.obs import names

# BENCH_sweep.json ladder-fill record schemas.  Schema 5 = schema 4 plus
# the producer-side generation truth and the trace pointer; the schema-4
# fields stay bit-compatible (same names, same rounding).
SCHEMA4_FIELDS = (
    "ladder", "n_systems", "n_members", "n_workloads", "sim_n",
    "dispatch_compiles", "one_compile", "devices", "mesh",
    "chunk", "chunk_auto", "n_chunks", "backend", "block",
    "t_shards", "t_rounds", "trace_gen_wall_s", "compile_plus_sim_wall_s",
)
SCHEMA5_FIELDS = SCHEMA4_FIELDS + ("trace_gen_true_wall_s", "trace_file")
# Schema 6 = schema 5 plus the fill's core count (1 for single-core
# ladders; C for multicore families running multiprogrammed mixes).
# Schema-5 fields stay bit-compatible — same names, same rounding.
SCHEMA6_FIELDS = SCHEMA5_FIELDS + ("cores",)

# field -> (kind, arg) derivation source, all rooted at one ladder_fill
# span subtree:
#   attr            fill-span attribute `arg`
#   sum_span_dur    round(sum of dur_s over descendant spans named `arg`, 3)
#   count_compiles  number of descendant EV_COMPILE events whose fn attr
#                   equals the fill's `arg` attribute (run_systems vs the
#                   per-chunk round_fn of the time-shard path)
#   derived         computed from other derived fields (`arg` names them)
#   trace_path      the JSONL file the events came from
FIELD_SOURCES = {
    "ladder": ("attr", "ladder"),
    "n_systems": ("attr", "n_systems"),
    "n_members": ("attr", "n_members"),
    "n_workloads": ("attr", "n_workloads"),
    "sim_n": ("attr", "sim_n"),
    "dispatch_compiles": ("count_compiles", "dispatch_fn"),
    "one_compile": ("derived", "dispatch_compiles"),
    "devices": ("attr", "devices"),
    "mesh": ("attr", "mesh"),
    "chunk": ("attr", "chunk"),
    "chunk_auto": ("attr", "chunk_auto"),
    "n_chunks": ("attr", "n_chunks"),
    "backend": ("attr", "backend"),
    "block": ("attr", "block"),
    "t_shards": ("attr", "t_shards"),
    "t_rounds": ("attr", "t_rounds"),
    "trace_gen_wall_s": ("sum_span_dur", names.SPAN_CHUNK_WAIT),
    "compile_plus_sim_wall_s": ("sum_span_dur", names.SPAN_DISPATCH),
    "trace_gen_true_wall_s": ("sum_span_dur", names.SPAN_TRACE_GEN),
    "trace_file": ("trace_path", None),
    "cores": ("attr", "cores"),
}


# BENCH_serve.json serving-run record schema — the load harness's
# analogue of the ladder-fill record, rooted at one serve.load_run span
# subtree.  Same discipline: the producer (serve.load.run_trace) does
# not hand-assemble its SERVE_PERF record; it closes the run span and
# calls serve_record on the tracer's in-memory events — the same
# function the CLI applies to the JSONL, so `report --check` is
# bit-exact for BENCH_serve exactly like BENCH_sweep.
SERVE_FIELDS = (
    "run", "arrival", "rate", "lanes", "mesh", "devices",
    "n_slots", "n_pool_pages", "gate", "n_ticks", "n_arrivals",
    "admitted", "rejected", "retired", "pool_stall", "invalidated",
    "decode_p50_s", "decode_p99_s", "decode_mean_s", "wall_s",
    "throughput_rps", "vtc_hit_tc", "vtc_hit_cluster", "vtc_walk",
    "vtc_hit_rate", "trace_file",
)

# field -> (kind, arg) sources for SERVE_FIELDS, all rooted at one
# serve.load_run span subtree:
#   attr           run-span attribute `arg`
#   sum_counts     sum of `n` over descendant count records named `arg`
#   dur_quantile   `arg` = (span_name, p): quantile of descendant span
#                  durations named span_name, the registry's hist
#                  formula (p in {50, 99}; "mean" = sum/len), round 6
#   span_dur       the run span's own dur_s, round 3
#   derived        computed from other derived fields (`arg` names them)
#   trace_path     the JSONL file the events came from
SERVE_FIELD_SOURCES = {
    "run": ("attr", "run"),
    "arrival": ("attr", "arrival"),
    "rate": ("attr", "rate"),
    "lanes": ("attr", "lanes"),
    "mesh": ("attr", "mesh"),
    "devices": ("attr", "devices"),
    "n_slots": ("attr", "n_slots"),
    "n_pool_pages": ("attr", "n_pool_pages"),
    "gate": ("attr", "gate"),
    "n_ticks": ("attr", "n_ticks"),
    "n_arrivals": ("attr", "n_arrivals"),
    "admitted": ("sum_counts", names.CTR_REQS_ADMITTED),
    "rejected": ("sum_counts", names.CTR_POOL_EXHAUSTED),
    "retired": ("sum_counts", names.CTR_REQS_RETIRED),
    "pool_stall": ("attr", "pool_stall"),
    "invalidated": ("sum_counts", names.CTR_VTC_INVALIDATE),
    "decode_p50_s": ("dur_quantile", (names.SPAN_DECODE_STEP, 50)),
    "decode_p99_s": ("dur_quantile", (names.SPAN_DECODE_STEP, 99)),
    "decode_mean_s": ("dur_quantile", (names.SPAN_DECODE_STEP, "mean")),
    "wall_s": ("span_dur", None),
    "throughput_rps": ("derived", ("retired", "wall_s")),
    "vtc_hit_tc": ("attr", "vtc_hit_tc"),
    "vtc_hit_cluster": ("attr", "vtc_hit_cluster"),
    "vtc_walk": ("attr", "vtc_walk"),
    "vtc_hit_rate": ("derived",
                     ("vtc_hit_tc", "vtc_hit_cluster", "vtc_walk")),
    "trace_file": ("trace_path", None),
}


def read_trace(path: str) -> list[dict]:
    """Parse a JSONL trace back into the tracer's event-list form."""
    events = []
    with open(path, encoding="utf-8") as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            rec = json.loads(line)
            if rec.get("kind") != "meta":
                events.append(rec)
    return events


def _descendants(events: list[dict], root_id: int) -> set[int]:
    """Ids of `root_id` and everything transitively parented under it."""
    kids: dict[int, list[int]] = {}
    for e in events:
        p = e.get("parent")
        if p is not None and "id" in e:
            kids.setdefault(p, []).append(e["id"])
    out, todo = {root_id}, [root_id]
    while todo:
        for c in kids.get(todo.pop(), ()):
            if c not in out:
                out.add(c)
                todo.append(c)
    return out


def fill_spans(events: list[dict]) -> list[dict]:
    """All closed ladder_fill spans, in emission (= completion) order."""
    return [e for e in events
            if e.get("kind") == "span"
            and e.get("name") == names.SPAN_LADDER_FILL]


def fill_record(events: list[dict], fill_id: int | None = None,
                trace_file: str | None = None) -> dict:
    """Derive one schema-6 ladder-fill record from a fill's span subtree.

    `events` is either ``tracer().events`` (live) or
    :func:`read_trace` output (offline) — identical by construction.
    `fill_id` picks the fill span (default: the only/last one).
    """
    fills = fill_spans(events)
    if fill_id is not None:
        fills = [f for f in fills if f["id"] == fill_id]
    if not fills:
        raise ValueError(
            f"no closed '{names.SPAN_LADDER_FILL}' span"
            + (f" with id {fill_id}" if fill_id is not None else "")
            + " in trace")
    fill = fills[-1]
    sub = _descendants(events, fill["id"])
    attrs = fill["attrs"]

    # duration sums accumulate in emission order over full-precision
    # dur_s values, then round once — bit-identical live and offline
    sums: dict[str, float] = {}
    for e in events:
        if (e.get("kind") == "span" and e.get("id") in sub
                and e["id"] != fill["id"]):
            sums[e["name"]] = sums.get(e["name"], 0.0) + e["dur_s"]

    dispatch_fn = attrs.get("dispatch_fn")
    n_compiles = sum(
        1 for e in events
        if e.get("kind") == "event" and e.get("name") == names.EV_COMPILE
        and e.get("id") in sub and e["attrs"].get("fn") == dispatch_fn)

    rec: dict = {}
    for field in SCHEMA6_FIELDS:
        kind, arg = FIELD_SOURCES[field]
        if kind == "attr":
            rec[field] = attrs.get(arg)
        elif kind == "sum_span_dur":
            rec[field] = round(sums.get(arg, 0.0), 3)
        elif kind == "count_compiles":
            rec[field] = n_compiles
        elif kind == "derived":
            rec[field] = rec[arg] <= 1  # one_compile
        elif kind == "trace_path":
            rec[field] = trace_file
        else:  # pragma: no cover - FIELD_SOURCES is closed by OB001
            raise ValueError(f"unknown source kind {kind!r} for {field!r}")
    return rec


def ladder_records(events: list[dict],
                   trace_file: str | None = None) -> list[dict]:
    """One derived record per closed ladder_fill span, in order."""
    return [fill_record(events, f["id"], trace_file)
            for f in fill_spans(events)]


# ----------------------------------------------------- serve records

def serve_spans(events: list[dict]) -> list[dict]:
    """All closed serve.load_run spans, in emission order."""
    return [e for e in events
            if e.get("kind") == "span"
            and e.get("name") == names.SPAN_SERVE_RUN]


def _quantile(samples: list[float], p) -> float | None:
    """The registry's hist-stats quantile on a sorted copy (round 6)."""
    if not samples:
        return None
    s = sorted(samples)
    if p == "mean":
        return round(sum(s) / len(s), 6)
    return round(s[min(len(s) - 1, int(len(s) * p / 100))], 6)


def serve_record(events: list[dict], run_id: int | None = None,
                 trace_file: str | None = None) -> dict:
    """Derive one BENCH_serve record from a serve.load_run span subtree.

    Mirrors :func:`fill_record`: `events` is ``tracer().events`` (live)
    or :func:`read_trace` output (offline) — identical by construction,
    so the offline reconstruction is bit-exact.
    """
    runs = serve_spans(events)
    if run_id is not None:
        runs = [r for r in runs if r["id"] == run_id]
    if not runs:
        raise ValueError(
            f"no closed '{names.SPAN_SERVE_RUN}' span"
            + (f" with id {run_id}" if run_id is not None else "")
            + " in trace")
    run = runs[-1]
    sub = _descendants(events, run["id"])
    attrs = run["attrs"]

    count_sums: dict[str, int] = {}
    durs: dict[str, list[float]] = {}
    for e in events:
        if e.get("id") not in sub or e["id"] == run["id"]:
            continue
        if e.get("kind") == "count":
            count_sums[e["name"]] = count_sums.get(e["name"], 0) \
                + e.get("n", 1)
        elif e.get("kind") == "span":
            durs.setdefault(e["name"], []).append(e["dur_s"])

    rec: dict = {}
    for field in SERVE_FIELDS:
        kind, arg = SERVE_FIELD_SOURCES[field]
        if kind == "attr":
            rec[field] = attrs.get(arg)
        elif kind == "sum_counts":
            rec[field] = count_sums.get(arg, 0)
        elif kind == "dur_quantile":
            rec[field] = _quantile(durs.get(arg[0], []), arg[1])
        elif kind == "span_dur":
            rec[field] = round(run["dur_s"], 3)
        elif kind == "derived":
            if field == "throughput_rps":
                rec[field] = (round(rec["retired"] / rec["wall_s"], 3)
                              if rec["wall_s"] else None)
            elif field == "vtc_hit_rate":
                hit = (rec["vtc_hit_tc"] or 0) \
                    + (rec["vtc_hit_cluster"] or 0)
                tot = hit + (rec["vtc_walk"] or 0)
                rec[field] = round(hit / max(tot, 1), 6)
            else:  # pragma: no cover - closed by OB001
                raise ValueError(f"unknown derived field {field!r}")
        elif kind == "trace_path":
            rec[field] = trace_file
        else:  # pragma: no cover - SERVE_FIELD_SOURCES is closed by OB001
            raise ValueError(f"unknown source kind {kind!r} for {field!r}")
    return rec


def serve_records(events: list[dict],
                  trace_file: str | None = None) -> list[dict]:
    """One derived record per closed serve.load_run span, in order."""
    return [serve_record(events, r["id"], trace_file)
            for r in serve_spans(events)]


# ----------------------------------------------------------- CLI verbs

def rollup(events: list[dict], trace_file: str | None = None) -> dict:
    """Human-oriented trace summary: fills, span totals, counters."""
    span_totals: dict[str, dict] = {}
    for e in events:
        if e.get("kind") == "span":
            t = span_totals.setdefault(e["name"], {"count": 0, "dur_s": 0.0})
            t["count"] += 1
            t["dur_s"] += e["dur_s"]
    for t in span_totals.values():
        t["dur_s"] = round(t["dur_s"], 6)
    ev_counts: dict[str, int] = {}
    for e in events:
        if e.get("kind") == "event":
            ev_counts[e["name"]] = ev_counts.get(e["name"], 0) + 1
    counters: dict[str, float] = {}
    for e in events:
        if e.get("kind") == "count":
            counters[e["name"]] = counters.get(e["name"], 0) + e.get("n", 1)
    metrics = [e["data"] for e in events if e.get("kind") == "metrics"]
    return {
        "trace_file": trace_file,
        "n_events": len(events),
        "fills": ladder_records(events, trace_file),
        "serve_runs": serve_records(events, trace_file),
        "spans": span_totals,
        "events": ev_counts,
        "counters": counters,
        "metrics": metrics[-1] if metrics else None,
    }


def check(events: list[dict], bench: dict,
          trace_file: str | None = None) -> list[str]:
    """Verify a BENCH_sweep artifact against its trace, field by field.

    Every ``ladder_fills`` record must be reproduced bit-exactly by the
    trace-derived record at the same position — schema-4 fields always;
    schema-5/6 extras when the artifact carries them.  A BENCH_serve
    artifact's ``serve_runs`` records get the identical positional
    treatment against :func:`serve_records`.  Returns a list of
    mismatch strings (empty = pass).
    """
    problems: list[str] = []
    want = bench.get("ladder_fills", [])
    got = ladder_records(events, trace_file)
    if len(want) != len(got):
        problems.append(
            f"artifact has {len(want)} ladder_fills but trace derives "
            f"{len(got)} fill records")
    for i, (w, g) in enumerate(zip(want, got)):
        for field in SCHEMA6_FIELDS:
            if field not in w:
                continue  # schema-4 artifact: extras absent, fine
            if field == "trace_file":
                continue  # path differs across machines by design
            if w[field] != g[field]:
                problems.append(
                    f"fill[{i}] field {field!r}: artifact has "
                    f"{w[field]!r}, trace derives {g[field]!r}")
    want_s = bench.get("serve_runs", [])
    got_s = serve_records(events, trace_file) if want_s else []
    if want_s and len(want_s) != len(got_s):
        problems.append(
            f"artifact has {len(want_s)} serve_runs but trace derives "
            f"{len(got_s)} serve records")
    for i, (w, g) in enumerate(zip(want_s, got_s)):
        for field in SERVE_FIELDS:
            if field not in w:
                continue
            if field == "trace_file":
                continue  # path differs across machines by design
            if w[field] != g[field]:
                problems.append(
                    f"serve_run[{i}] field {field!r}: artifact has "
                    f"{w[field]!r}, trace derives {g[field]!r}")
    return problems


def diff(old: dict, new: dict, warn_pct: float = 20.0) -> dict:
    """Compare two BENCH_sweep artifacts' wall times, fill by fill.

    Fills are matched on their configuration key (ladder, sim_n,
    workload count, backend, chunk, time shards); unmatched fills are
    listed, not errors.  A matched fill whose wall time grew more than
    `warn_pct` percent lands in ``regressions``.
    """
    def keyed(art):
        out = {}
        for r in art.get("ladder_fills", []):
            k = (r.get("ladder"), r.get("sim_n"), r.get("n_workloads"),
                 r.get("backend"), r.get("chunk"), r.get("t_shards"))
            out.setdefault(k, []).append(r)
        return out

    ko, kn = keyed(old), keyed(new)
    rows, regressions = [], []
    for k in kn:
        for i, r_new in enumerate(kn[k]):
            r_old = ko.get(k, [])[i] if i < len(ko.get(k, [])) else None
            if r_old is None:
                rows.append({"key": list(k), "status": "new-only"})
                continue
            row = {"key": list(k), "status": "matched"}
            for field in ("compile_plus_sim_wall_s", "trace_gen_wall_s"):
                a, b = r_old.get(field), r_new.get(field)
                row[field] = {"old": a, "new": b}
                if a and b is not None and a > 0:
                    pct = 100.0 * (b - a) / a
                    row[field]["pct"] = round(pct, 1)
                    if pct > warn_pct:
                        regressions.append(
                            f"{k}: {field} {a} -> {b} (+{pct:.1f}% > "
                            f"{warn_pct:g}% threshold)")
            rows.append(row)
    only_old = [list(k) for k in ko if k not in kn]
    return {"fills": rows, "old_only": only_old,
            "regressions": regressions, "warn_pct": warn_pct}
