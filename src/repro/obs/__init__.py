"""``repro.obs`` — unified tracing + metrics for the whole repo.

One process-global span :class:`~repro.obs.tracer.Tracer` (JSONL sink,
see ``tracer``) and one metrics :class:`~repro.obs.registry.Registry`
(counters/gauges/histograms, see ``registry``), behind a module-level
facade so call sites stay one-liners::

    import repro.obs as obs

    with obs.span(obs.names.SPAN_LADDER_FILL, ladder="native") as fill:
        ...
        with obs.span(obs.names.SPAN_DISPATCH, chunk_i=0):
            ...
    rec = obs.report.fill_record(obs.tracer().events, fill.id,
                                 obs.tracer().path)

Work on other threads passes ``parent=fill`` explicitly (thread-local
implicit nesting does not cross threads).  ``python -m repro.obs
report`` reconstructs BENCH records from the JSONL — see ``report``.

Stdlib-only at import time (jax only inside ``jaxprof`` helpers), so
any layer — ``sim.parallel`` included — may import it without cycles.
"""
from __future__ import annotations

from repro.obs import jaxprof, names, report  # noqa: F401  (re-export)
from repro.obs.registry import Registry
from repro.obs.tracer import Span, Tracer  # noqa: F401  (re-export)

_TRACER: Tracer | None = None
REGISTRY = Registry()


def tracer() -> Tracer:
    """The process-global tracer (created lazily at the env-derived path)."""
    global _TRACER
    if _TRACER is None:
        _TRACER = Tracer()
    return _TRACER


def configure(path: str | None = None) -> Tracer:
    """Point the global tracer at `path` (e.g. from ``--obs-trace``).

    Replaces the singleton; the previous tracer (if any) is closed.
    """
    global _TRACER
    if _TRACER is not None:
        _TRACER.close()
    _TRACER = Tracer(path)
    return _TRACER


# ------------------------------------------------------- span facade

def span(name: str, parent=None, **attrs) -> Span:
    return tracer().span(name, parent=parent, **attrs)


def event(name: str, parent=None, **attrs) -> dict:
    return tracer().event(name, parent=parent, **attrs)


def current() -> Span | None:
    return tracer().current()


def overhead_s() -> float:
    return tracer().overhead_s


# ---------------------------------------------------- metrics facade

def count(name: str, n=1, emit: bool = False, **attrs):
    """Bump registry counter `name`; with ``emit=True`` also write a
    ``count`` record to the trace.  Tracer-valued `n` is skipped (jit
    tracing) — returns the applied delta or None."""
    applied = REGISTRY.inc(name, n)
    if emit and applied is not None:
        tracer().count(name, applied, **attrs)
    return applied


def gauge(name: str, v):
    return REGISTRY.gauge(name, v)


def observe(name: str, v):
    return REGISTRY.observe(name, v)


def metrics_snapshot(emit: bool = False) -> dict:
    """Registry snapshot; with ``emit=True`` also append it to the trace."""
    snap = REGISTRY.snapshot()
    if emit:
        tracer().metrics(snap)
    return snap
