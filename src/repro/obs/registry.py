"""Counters / gauges / histograms registry — the serving-side metrics path.

One process-global :class:`Registry` (held by ``repro.obs``) that
``serve.engine`` and the VTC emit into, so the future serving load
harness reads every rate/latency from ONE place instead of ad-hoc
dict math.  All updates are lock-protected and **tracer-safe**: a value
that is still a jax tracer (the caller is being jit-traced) is silently
skipped — metrics are host-side telemetry, never part of a compiled
graph.  Use :func:`host_value` directly to apply the same guard to
custom emission.

Histograms keep running count/sum/min/max plus a bounded sample
reservoir (first ``HIST_KEEP`` observations) — enough for the p50/p95/
p99 the serving benchmarks report without unbounded memory.

Stdlib-only, like the rest of ``repro.obs`` (see ``tracer``).
"""
from __future__ import annotations

import threading

HIST_KEEP = 4096  # per-histogram sample cap (first-N reservoir)


def host_value(v):
    """Coerce to a host int/float, or None when `v` is a jax tracer
    (or anything else that cannot concretize to a scalar)."""
    if isinstance(v, bool):
        return int(v)
    if isinstance(v, (int, float)):
        return v
    try:
        # concrete 0-d jax/numpy arrays concretize; tracers raise
        # (ConcretizationTypeError subclasses TypeError)
        f = float(v)
    except Exception:
        return None
    # integer-typed device scalars stay ints (dtype.kind avoids a numpy
    # dependency: this module is stdlib-only)
    if getattr(getattr(v, "dtype", None), "kind", None) in "iub":
        return int(f)
    return f


class Registry:
    """Thread-safe named counters, gauges, and histograms."""

    def __init__(self):
        self._lock = threading.Lock()
        self._counters: dict[str, float] = {}
        self._gauges: dict[str, float] = {}
        self._hists: dict[str, dict] = {}

    # ------------------------------------------------------- updates

    def inc(self, name: str, n=1):
        """Bump a counter by `n`.  Returns the applied delta, or None
        when `n` was a tracer (update skipped)."""
        n = host_value(n)
        if n is None:
            return None
        with self._lock:
            self._counters[name] = self._counters.get(name, 0) + n
        return n

    def inc_to(self, name: str, v):
        """Raise a counter to cumulative value `v` (monotone: a no-op
        when already >= v).  For sources that keep their own running
        totals — the VTC's in-state hit counters — where repeated
        sampling must be idempotent.  Tracer → skipped."""
        v = host_value(v)
        if v is None:
            return None
        with self._lock:
            self._counters[name] = max(self._counters.get(name, 0), v)
        return v

    def gauge(self, name: str, v):
        """Set a gauge to `v` (last-write-wins).  Tracer → skipped."""
        v = host_value(v)
        if v is None:
            return None
        with self._lock:
            self._gauges[name] = v
        return v

    def observe(self, name: str, v):
        """Record one histogram observation.  Tracer → skipped."""
        v = host_value(v)
        if v is None:
            return None
        with self._lock:
            h = self._hists.get(name)
            if h is None:
                h = self._hists[name] = {
                    "count": 0, "sum": 0.0, "min": v, "max": v,
                    "samples": []}
            h["count"] += 1
            h["sum"] += v
            h["min"] = min(h["min"], v)
            h["max"] = max(h["max"], v)
            if len(h["samples"]) < HIST_KEEP:
                h["samples"].append(v)
        return v

    # ------------------------------------------------------- reads

    def counter(self, name: str):
        with self._lock:
            return self._counters.get(name, 0)

    def hist_stats(self, name: str) -> dict | None:
        """count/sum/mean/min/max/p50/p95/p99 for one histogram."""
        with self._lock:
            h = self._hists.get(name)
            if h is None:
                return None
            s = sorted(h["samples"])
            out = {"count": h["count"], "sum": h["sum"],
                   "mean": h["sum"] / max(h["count"], 1),
                   "min": h["min"], "max": h["max"]}
        for p in (50, 95, 99):
            out[f"p{p}"] = s[min(len(s) - 1, int(len(s) * p / 100))] \
                if s else None
        return out

    def snapshot(self) -> dict:
        """Plain-dict view of everything (histograms as summary stats)."""
        with self._lock:
            hist_names = list(self._hists)
            out = {"counters": dict(self._counters),
                   "gauges": dict(self._gauges)}
        out["hists"] = {n: self.hist_stats(n) for n in hist_names}
        return out

    def reset(self) -> None:
        with self._lock:
            self._counters.clear()
            self._gauges.clear()
            self._hists.clear()
