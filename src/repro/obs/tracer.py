"""Structured span tracer with a JSONL event sink.

One :class:`Tracer` serializes every record it emits — span closes,
instant events, counter bumps — as one JSON line, appended to a
per-process trace file AND kept in an in-memory list, so the same
derivation code (``repro.obs.report``) can build ``BENCH_sweep``
records live (``runner.LADDER_PERF``) and reconstruct them offline
from the file, bit-exactly.

Threading model: the tracer is fully thread-safe.  Each thread carries
its own *implicit* span stack (``threading.local``), so nested ``with
span(...)`` blocks parent naturally within a thread; work handed to a
different thread (``run_ladder``'s producer pool) attaches to the right
fill via an *explicit* ``parent=`` handle — a :class:`Span` or its
integer id.  Record emission (id allocation, list append, file write)
happens under one lock.

Records are sanitized to plain JSON values at emission time
(numpy/jax scalars become Python numbers), which is what makes the
file ↔ memory round trip exact: ``json.loads(json.dumps(rec)) == rec``.

The sink path resolves lazily: ``REPRO_OBS_TRACE`` names an explicit
file; otherwise traces land in ``REPRO_OBS_DIR`` (default
``.obs_trace/`` next to the sim cache) as ``trace-<pid>.jsonl``.  The
file itself is only created when the first record is emitted — an
import alone never touches the filesystem.

This module deliberately imports nothing from ``repro`` (stdlib only),
so every layer — ``sim.parallel`` included, which otherwise imports no
repro siblings — can emit into it without a cycle.
"""
from __future__ import annotations

import itertools
import json
import os
import threading
import time

SCHEMA = 1  # JSONL record schema (the "meta" header line carries it)

_DEFAULT_DIR = os.path.join(
    os.path.dirname(os.environ.get("REPRO_SIM_CACHE",
                                   "/root/repo/.sim_cache")), ".obs_trace")


def default_path() -> str:
    """The sink path a fresh tracer would write to (env-resolved)."""
    env = os.environ.get("REPRO_OBS_TRACE", "").strip()
    if env:
        return env
    d = os.environ.get("REPRO_OBS_DIR", "").strip() or _DEFAULT_DIR
    return os.path.join(d, f"trace-{os.getpid()}.jsonl")


def _jsonable(v):
    """Coerce an attr value to a plain JSON value (or raise).

    numpy/jax scalars carry ``.item()``; arrays become lists via
    ``.tolist()``.  Anything else non-JSON is repr'd — attrs are
    telemetry, a lossy string beats a crashed sweep — EXCEPT under the
    round-trip-critical kinds, which only ever receive plain values.
    """
    if v is None or isinstance(v, (bool, int, float, str)):
        return v
    if isinstance(v, dict):
        return {str(k): _jsonable(x) for k, x in v.items()}
    if isinstance(v, (list, tuple)):
        return [_jsonable(x) for x in v]
    if hasattr(v, "item") and getattr(v, "ndim", None) in (0, None):
        try:
            return _jsonable(v.item())
        except Exception:
            pass
    if hasattr(v, "tolist"):
        try:
            return _jsonable(v.tolist())
        except Exception:
            pass
    return repr(v)


class Span:
    """A handle for an open span: settable attrs, explicit-parent anchor.

    Created via :meth:`Tracer.span`; use as a context manager.  The
    record is emitted at CLOSE time (one line per span), carrying
    ``t0`` (wall clock at open), ``dur_s`` (monotonic duration), the
    span ``id``, its ``parent`` id and ``thread`` name.
    """

    __slots__ = ("tracer", "name", "id", "parent_id", "attrs",
                 "_t0_wall", "_t0_mono", "_closed")

    def __init__(self, tracer: "Tracer", name: str, span_id: int,
                 parent_id: int | None, attrs: dict):
        self.tracer = tracer
        self.name = name
        self.id = span_id
        self.parent_id = parent_id
        self.attrs = attrs
        self._t0_wall = time.time()
        self._t0_mono = time.perf_counter()
        self._closed = False

    def set(self, **attrs) -> "Span":
        """Attach/override attrs before the span closes."""
        self.attrs.update(attrs)
        return self

    def __enter__(self) -> "Span":
        self.tracer._push(self)
        return self

    def __exit__(self, *exc) -> None:
        self.close(error=bool(exc and exc[0] is not None))

    def close(self, error: bool = False) -> None:
        if self._closed:
            return
        self._closed = True
        dur = time.perf_counter() - self._t0_mono
        self.tracer._pop(self)
        rec = {"kind": "span", "name": self.name, "id": self.id,
               "parent": self.parent_id,
               "thread": threading.current_thread().name,
               "t0": self._t0_wall, "dur_s": dur,
               "attrs": {k: _jsonable(v) for k, v in self.attrs.items()}}
        if error:
            rec["error"] = True
        self.tracer._emit(rec)


class Tracer:
    """Thread-safe span tracer + JSONL sink (see module docstring).

    ``overhead_s`` accumulates the monotonic time spent *inside* record
    emission (serialize + append + write) — the number the <2%%-of-sim
    overhead acceptance test bounds.
    """

    def __init__(self, path: str | None = None):
        self._lock = threading.Lock()
        self._local = threading.local()
        self._ids = itertools.count(1)
        self._path = path or default_path()
        self._file = None
        self.events: list[dict] = []
        self.overhead_s = 0.0

    @property
    def path(self) -> str:
        return self._path

    # ------------------------------------------------- span plumbing

    def _stack(self) -> list:
        st = getattr(self._local, "stack", None)
        if st is None:
            st = self._local.stack = []
        return st

    def _push(self, sp: Span) -> None:
        self._stack().append(sp)

    def _pop(self, sp: Span) -> None:
        st = self._stack()
        if sp in st:
            # tolerate out-of-order closes (explicit .close() calls)
            st.remove(sp)

    def current(self) -> Span | None:
        """This thread's innermost open span (implicit parent)."""
        st = self._stack()
        return st[-1] if st else None

    @staticmethod
    def _parent_id(parent) -> int | None:
        if parent is None:
            return None
        return parent.id if isinstance(parent, Span) else int(parent)

    def span(self, name: str, parent: Span | int | None = None,
             **attrs) -> Span:
        """Open a span.  ``parent`` overrides the implicit thread-local
        parent — REQUIRED when the span runs on a different thread than
        the logical parent (e.g. producer-pool trace generation)."""
        pid = (self._parent_id(parent) if parent is not None
               else (self.current().id if self.current() else None))
        with self._lock:
            sid = next(self._ids)
        return Span(self, name, sid, pid, dict(attrs))

    def event(self, name: str, parent: Span | int | None = None,
              **attrs) -> dict:
        """Emit an instant event record."""
        pid = (self._parent_id(parent) if parent is not None
               else (self.current().id if self.current() else None))
        with self._lock:
            sid = next(self._ids)
        rec = {"kind": "event", "name": name, "id": sid, "parent": pid,
               "t": time.time(),
               "attrs": {k: _jsonable(v) for k, v in attrs.items()}}
        self._emit(rec)
        return rec

    def count(self, name: str, n=1, parent: Span | int | None = None,
              **attrs) -> dict:
        """Emit a counter-bump record (the registry increment is the
        caller's job — ``repro.obs.count`` does both)."""
        pid = (self._parent_id(parent) if parent is not None
               else (self.current().id if self.current() else None))
        with self._lock:
            sid = next(self._ids)
        rec = {"kind": "count", "name": name, "id": sid, "parent": pid,
               "t": time.time(), "n": _jsonable(n),
               "attrs": {k: _jsonable(v) for k, v in attrs.items()}}
        self._emit(rec)
        return rec

    def metrics(self, snapshot: dict) -> dict:
        """Emit a metrics-registry snapshot record."""
        rec = {"kind": "metrics", "t": time.time(),
               "data": _jsonable(snapshot)}
        self._emit(rec)
        return rec

    # ------------------------------------------------------ the sink

    def _open(self):
        d = os.path.dirname(self._path)
        if d:
            os.makedirs(d, exist_ok=True)
        f = open(self._path, "a", encoding="utf-8")
        if f.tell() == 0:
            f.write(json.dumps(
                {"kind": "meta", "schema": SCHEMA, "pid": os.getpid(),
                 "t": time.time()}) + "\n")
        return f

    def _emit(self, rec: dict) -> None:
        t0 = time.perf_counter()
        line = json.dumps(rec)
        with self._lock:
            self.events.append(rec)
            if self._file is None:
                self._file = self._open()
            self._file.write(line + "\n")
            self._file.flush()
            self.overhead_s += time.perf_counter() - t0

    def flush(self) -> None:
        with self._lock:
            if self._file is not None:
                self._file.flush()

    def close(self) -> None:
        with self._lock:
            if self._file is not None:
                self._file.close()
                self._file = None
