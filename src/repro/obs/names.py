"""The span/event/metric taxonomy — every name the repo emits, declared.

``repro.obs`` records are free-form (any name string is accepted), but
the *pipeline* instrumentation emits only names declared here so the
OB001 analyzer pass can prove the schema-5 ``BENCH_sweep`` record is
fully derivable from the trace: ``repro.obs.report.FIELD_SOURCES`` maps
every record field to a span/event/attr source, and OB001 checks each
source references a declared name (see ``repro.analysis.obs_contract``).

Span taxonomy (docs/architecture.md, "Observability"):

  ladder_fill                 one ``runner.run_ladder`` fill (the unit
                              BENCH_sweep records); all other sweep
                              spans/events are its descendants
  ├─ trace_gen                one workload's trace generation, opened ON
  │                           the producer-pool worker thread with an
  │                           explicit ``parent=`` handle — producer-side
  │                           TRUE generation time
  ├─ chunk_wait               consumer-side wait for a chunk's traces
  │                           (generation NOT hidden behind simulation —
  │                           the legacy ``trace_gen_wall_s`` semantics)
  ├─ dispatch                 one compiled shard_map call over an
  │                           [S, chunk] block (+ host device_get)
  │   └─ time_shard_round     (event) one speculative hand-off round of
  │                           ``parallel.time_shard_scan``, with the
  │                           exact-known prefix after the round
  ├─ xla_compile              (event) one jit-cache miss captured by
  │                           ``analysis.recompile.count_compiles``,
  │                           carrying the compiled function's name
  ├─ pallas_kernel            (event, trace-time) a ``blocked_scan``
  │                           kernel build: block size, grid, interpret
  └─ device_memory            (event) live device-memory stats where the
                              backend exposes them (TPU phase-2 runs)

  serve.decode_step           one timed serving decode tick
                              (``serve.engine.decode_step``)

  serve.load_run              one serving load-harness run (the unit
                              BENCH_serve records); its descendants are
                              the per-tick ``serve.decode_step`` spans
                              and ``serve.*`` count events, so every
                              BENCH_serve field re-derives from the
                              run's subtree alone (OB001, schema-5
                              discipline)
"""
from __future__ import annotations

# ------------------------------------------------------------- spans
SPAN_LADDER_FILL = "ladder_fill"
SPAN_TRACE_GEN = "trace_gen"
SPAN_CHUNK_WAIT = "chunk_wait"
SPAN_DISPATCH = "dispatch"
SPAN_DECODE_STEP = "serve.decode_step"
SPAN_SERVE_RUN = "serve.load_run"

SPAN_NAMES = (SPAN_LADDER_FILL, SPAN_TRACE_GEN, SPAN_CHUNK_WAIT,
              SPAN_DISPATCH, SPAN_DECODE_STEP, SPAN_SERVE_RUN)

# ------------------------------------------------------------ events
EV_COMPILE = "xla_compile"
EV_TIME_SHARD_ROUND = "time_shard_round"
EV_PALLAS_KERNEL = "pallas_kernel"
EV_DEVICE_MEMORY = "device_memory"

EVENT_NAMES = (EV_COMPILE, EV_TIME_SHARD_ROUND, EV_PALLAS_KERNEL,
               EV_DEVICE_MEMORY)

# ------------------------------------------- counters / gauges / hists
CTR_SIM_CACHE_HIT = "sim_cache.hit"
CTR_SIM_CACHE_MISS = "sim_cache.miss"
CTR_SIM_CACHE_STORE = "sim_cache.store"
CTR_VTC_HIT_TC = "serve.vtc.hit_tc"
CTR_VTC_HIT_CLUSTER = "serve.vtc.hit_cluster"
CTR_VTC_WALK = "serve.vtc.walk"
CTR_VTC_INVALIDATE = "serve.vtc.invalidate"
CTR_DECODE_STEPS = "serve.decode_steps"
CTR_REQS_ADMITTED = "serve.admitted"
CTR_REQS_RETIRED = "serve.retired"
CTR_POOL_EXHAUSTED = "serve.pool_exhausted"

GAUGE_PAGES_FREE = "serve.pages_free"
GAUGE_SLOT_OCCUPANCY = "serve.slot_occupancy"

HIST_DECODE_STEP_S = "serve.decode_step_s"
HIST_REQ_TICKS = "serve.req_ticks"

COUNTER_NAMES = (CTR_SIM_CACHE_HIT, CTR_SIM_CACHE_MISS,
                 CTR_SIM_CACHE_STORE, CTR_VTC_HIT_TC, CTR_VTC_HIT_CLUSTER,
                 CTR_VTC_WALK, CTR_VTC_INVALIDATE, CTR_DECODE_STEPS,
                 CTR_REQS_ADMITTED, CTR_REQS_RETIRED, CTR_POOL_EXHAUSTED)
GAUGE_NAMES = (GAUGE_PAGES_FREE, GAUGE_SLOT_OCCUPANCY)
HIST_NAMES = (HIST_DECODE_STEP_S, HIST_REQ_TICKS)
