"""Unified model API: ``build(cfg)`` → Model with init/loss/prefill/decode.

Families: dense, moe, vlm (transformer backbone), ssm (mamba2),
hybrid (recurrentgemma), encdec (seamless).
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import encdec, moe, rglru, ssm, transformer
from repro.models import layers as L


def cross_entropy(logits, targets, mask=None):
    """Token CE in fp32. logits [B,S,V] (fp32), targets [B,S] int32."""
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is None:
        return jnp.mean(nll)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


class Model:
    """Thin namespace binding a ModelConfig to family implementations."""

    def __init__(self, cfg: ModelConfig, constrain: Callable = None):
        self.cfg = cfg
        self.constrain = constrain or (lambda t, kind: t)
        if cfg.family == "moe":
            self._ffn_init = moe.moe_init

            def ffn_apply(p, x):
                return moe.moe_apply(p, x, cfg)
        else:
            self._ffn_init = L.mlp_init

            def ffn_apply(p, x):
                return L.mlp_apply(p, x)
        self._ffn_apply = ffn_apply

    # ------------------------------------------------------------ init

    def init(self, key):
        cfg = self.cfg
        if cfg.family == "ssm":
            return ssm.init(key, cfg)
        if cfg.family == "hybrid":
            return rglru.init(key, cfg)
        if cfg.family == "encdec":
            return encdec.init(key, cfg)
        return transformer.init(key, cfg, self._ffn_init)

    # ------------------------------------------------------------ train

    def forward(self, params, batch, remat: bool = True):
        cfg, cons = self.cfg, self.constrain
        if cfg.family == "ssm":
            return ssm.forward(params, cfg, batch["tokens"], cons, remat)
        if cfg.family == "hybrid":
            return rglru.forward(params, cfg, batch["tokens"], cons, remat)
        if cfg.family == "encdec":
            return encdec.forward(params, cfg, batch["tokens"],
                                  batch["src_embeds"], cons, remat)
        return transformer.forward(
            params, cfg, batch["tokens"],
            positions3=batch.get("positions3"),
            input_embeds=batch.get("vision_embeds"),
            ffn_apply=self._ffn_apply, constrain=cons, remat=remat)

    def loss(self, params, batch, remat: bool = True):
        logits = self.forward(params, batch, remat)
        tokens = batch["tokens"]
        lv = cross_entropy(logits[:, :-1], tokens[:, 1:],
                           batch.get("loss_mask"))
        if self.cfg.family == "moe":
            # router balance term on the embedding stream (cheap proxy
            # computed once, standard aux-loss weight)
            x = L.embed_apply(params["embed"], tokens)
            lv = lv + 0.01 * moe.aux_loss(
                jax.tree.map(lambda a: a[0], params["layers"])["ffn"],
                x, self.cfg)
        return lv

    # ------------------------------------------------------------ serve

    def init_cache(self, batch: int, seq_len: int, dtype=jnp.bfloat16):
        cfg = self.cfg
        if cfg.family == "ssm":
            return ssm.init_cache(cfg, batch, seq_len, dtype)
        if cfg.family == "hybrid":
            return rglru.init_cache(cfg, batch, seq_len, dtype)
        if cfg.family == "encdec":
            return encdec.init_cache(cfg, batch, seq_len, dtype)
        return transformer.init_cache(cfg, batch, seq_len, dtype)

    def prefill(self, params, batch):
        """Returns (logits_last, cache) for transformer families; SSM and
        hybrid prefill via forward-with-state (their cache is O(1))."""
        cfg, cons = self.cfg, self.constrain
        if cfg.family in ("dense", "moe", "vlm"):
            return transformer.prefill(
                params, cfg, batch["tokens"],
                positions3=batch.get("positions3"),
                input_embeds=batch.get("vision_embeds"),
                ffn_apply=self._ffn_apply, constrain=cons)
        # ssm/hybrid/encdec prefill = forward (state-carrying variants are
        # exercised through decode); logits of last position returned
        logits = self.forward(params, batch, remat=False)
        return logits[:, -1:], None

    def decode_step(self, params, cache, tokens, pos, extras=None):
        cfg, cons = self.cfg, self.constrain
        extras = extras or {}
        if cfg.family == "ssm":
            return ssm.decode_step(params, cfg, cache, tokens, pos, cons)
        if cfg.family == "hybrid":
            return rglru.decode_step(params, cfg, cache, tokens, pos, cons)
        if cfg.family == "encdec":
            return encdec.decode_step(params, cfg, cache, tokens, pos, cons)
        positions3 = extras.get("positions3")
        if cfg.family == "vlm" and positions3 is None:
            positions3 = jnp.stack([pos[:, None]] * 3)  # text: t=h=w=pos
        return transformer.decode_step(
            params, cfg, cache, tokens, pos, positions3=positions3,
            ffn_apply=self._ffn_apply, constrain=cons)


def build(cfg: ModelConfig, constrain=None) -> Model:
    return Model(cfg, constrain)


def dummy_batch(cfg: ModelConfig, batch: int, seq: int, key=None):
    """Concrete small inputs for smoke tests (frontends stubbed)."""
    key = key if key is not None else jax.random.PRNGKey(0)
    toks = jax.random.randint(key, (batch, seq), 0, cfg.vocab_size,
                              dtype=jnp.int32)
    out = {"tokens": toks}
    if cfg.family == "vlm":
        P = min(cfg.n_patches, seq // 2)
        out["vision_embeds"] = jnp.zeros((batch, P, cfg.d_model),
                                         jnp.bfloat16)
        pos = jnp.broadcast_to(jnp.arange(seq)[None], (batch, seq))
        out["positions3"] = jnp.stack([pos] * 3)
    if cfg.family == "encdec":
        out["src_embeds"] = jax.random.normal(
            key, (batch, seq, cfg.d_model), jnp.bfloat16) * 0.02
    return out
