"""Shared transformer building blocks (pure-functional, pjit-friendly).

Params are nested dicts of jnp arrays; every layer provides
``init(key, cfg) -> params`` and ``apply(params, ...) -> out``.  Activation
sharding constraints are applied by the caller (``repro.dist.sharding``) —
layers stay mesh-agnostic.  All matmuls accumulate in fp32
(``preferred_element_type``) and cast back to the activation dtype, which
is the TPU-idiomatic MXU pattern.
"""
from __future__ import annotations

import os
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


def dtype_of(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def dense_init(key, shape, in_axis=0, dtype=jnp.float32):
    fan_in = shape[in_axis]
    return (jax.random.normal(key, shape, jnp.float32)
            * (1.0 / jnp.sqrt(fan_in))).astype(dtype)


_CPU = jax.default_backend() == "cpu"


def einsum_f32(spec, *ops, out_dtype=None):
    """einsum with fp32 accumulation (MXU-idiomatic on TPU).

    The CPU DotThunk lacks several bf16×bf16→f32 batched-dot kernels, so on
    the CPU backend operands are upcast instead — numerically identical
    (fp32 accumulate), TPU path untouched."""
    if _CPU and any(o.dtype == jnp.bfloat16 for o in ops):
        y = jnp.einsum(spec, *[o.astype(jnp.float32) for o in ops])
    else:
        y = jnp.einsum(spec, *ops, preferred_element_type=jnp.float32)
    return y if out_dtype is None else y.astype(out_dtype)


def matmul(x, w):
    """bf16 × bf16 → fp32 accumulate → bf16 (MXU-shaped)."""
    return einsum_f32("...d,df->...f", x, w, out_dtype=x.dtype)


# ---------------------------------------------------------------- norms


def rms_norm_init(d: int):
    return {"scale": jnp.ones((d,), jnp.float32)}


def rms_norm(p, x, eps: float):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps) * p["scale"]
    return y.astype(x.dtype)


# ---------------------------------------------------------------- RoPE


def rope_freqs(head_dim: int, theta: float):
    return theta ** (-jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                     / head_dim)


def apply_rope(x, positions, theta: float):
    """x: [..., S, H, hd]; positions: [..., S] (broadcastable)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # [hd/2]
    ang = positions[..., None].astype(jnp.float32) * freqs  # [..., S, hd/2]
    ang = ang[..., None, :]                             # [..., S, 1, hd/2]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


def apply_mrope(x, positions3, theta: float, sections):
    """Multimodal RoPE (qwen2-vl): positions3 [3, B, S] are the (t, h, w)
    position-id streams; `sections` split the hd/2 rotary dims among them."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # [hd/2]
    sec = jnp.cumsum(jnp.asarray(sections))
    idx = jnp.arange(hd // 2)
    which = ((idx >= sec[0]).astype(jnp.int32)
             + (idx >= sec[1]).astype(jnp.int32))       # [hd/2] ∈ {0,1,2}
    pos_j = positions3[which]                           # [hd/2, B, S]
    ang = (jnp.moveaxis(pos_j, 0, -1).astype(jnp.float32) * freqs)  # [B,S,hd/2]
    ang = ang[..., None, :]                             # [B, S, 1, hd/2]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------- attention


def attn_init(key, cfg: ModelConfig):
    D, H, K, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    dt = dtype_of(cfg)
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (D, H * hd), dtype=dt),
        "wk": dense_init(ks[1], (D, K * hd), dtype=dt),
        "wv": dense_init(ks[2], (D, K * hd), dtype=dt),
        "wo": dense_init(ks[3], (H * hd, D), dtype=dt),
    }
    if cfg.qk_norm:
        p["q_norm"] = rms_norm_init(hd)
        p["k_norm"] = rms_norm_init(hd)
    return p


def _mask_bias(q_pos, k_pos, causal: bool, window: Optional[int]):
    """Additive attention bias [..., Sq, Sk] from position ids."""
    d = q_pos[..., :, None] - k_pos[..., None, :]
    m = jnp.ones(d.shape, jnp.bool_)
    if causal:
        m = m & (d >= 0)
    if window is not None:
        m = m & (d < window)
    return jnp.where(m, 0.0, -1e30).astype(jnp.float32)


def attention_scores(q, k, v, bias):
    """q [B,Sq,H,hd], k/v [B,Sk,K,hd] (GQA: H % K == 0)."""
    B, Sq, H, hd = q.shape
    K = k.shape[2]
    G = H // K
    qg = q.reshape(B, Sq, K, G, hd)
    logits = einsum_f32("bqkgh,bskh->bkgqs", qg, k)
    logits = logits / jnp.sqrt(hd).astype(jnp.float32)
    logits = logits + bias[:, None, None, :, :]
    w = jax.nn.softmax(logits, axis=-1)
    out = einsum_f32("bkgqs,bskh->bqkgh", w.astype(v.dtype), v)
    return out.reshape(B, Sq, H, hd).astype(q.dtype)


def chunked_attention(q, k, v, q_pos, k_pos, causal: bool,
                      window: Optional[int], chunk: int = 1024):
    """Flash-style online-softmax attention, scanning KV in chunks.

    Pure-JAX analogue of the Pallas flash kernel (kernels/flash_attention):
    O(S·chunk) live memory instead of O(S²) — this is what long-sequence
    prefill lowers to in the dry-run (Pallas/Mosaic is TPU-only).
    q [B,Sq,H,hd]; k,v [B,Sk,K,hd]; q_pos [B,Sq]; k_pos [B,Sk].
    """
    B, Sq, H, hd = q.shape
    Sk, K = k.shape[1], k.shape[2]
    G = H // K
    assert Sk % chunk == 0, (Sk, chunk)
    nk = Sk // chunk
    qg = q.reshape(B, Sq, K, G, hd)
    ks = jnp.moveaxis(k.reshape(B, nk, chunk, K, hd), 1, 0)
    vs = jnp.moveaxis(v.reshape(B, nk, chunk, K, hd), 1, 0)
    kps = jnp.moveaxis(k_pos.reshape(B, nk, chunk), 1, 0)
    scale = 1.0 / jnp.sqrt(hd).astype(jnp.float32)

    def body(carry, inp):
        m, lse, acc = carry
        kc, vc, kpc = inp
        s = einsum_f32("bqkgh,bckh->bkgqc", qg, kc) * scale
        d = q_pos[:, None, None, :, None] - kpc[:, None, None, None, :]
        msk = jnp.ones_like(d, jnp.bool_)
        if causal:
            msk = msk & (d >= 0)
        if window is not None:
            msk = msk & (d < window)
        s = jnp.where(msk, s, -1e30)
        m2 = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m2[..., None])
        corr = jnp.exp(m - m2)
        l2 = lse * corr + jnp.sum(p, axis=-1)
        pv = einsum_f32("bkgqc,bckh->bkgqh", p.astype(vc.dtype), vc)
        acc2 = acc * corr[..., None] + pv
        return (m2, l2, acc2), ()

    m0 = jnp.full((B, K, G, Sq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((B, K, G, Sq), jnp.float32)
    a0 = jnp.zeros((B, K, G, Sq, hd), jnp.float32)
    (m, lse, acc), _ = jax.lax.scan(body, (m0, l0, a0), (ks, vs, kps))
    out = acc / jnp.maximum(lse, 1e-30)[..., None]
    out = jnp.moveaxis(out, 3, 1).reshape(B, Sq, K * G, hd)
    return out.astype(q.dtype)


_MASK_KV_UPDATE = os.environ.get("REPRO_MASK_KV", "0") == "1"

ATTN_CHUNK_THRESHOLD = 8192  # Sq·Sk above which the chunked path is used


def attn_apply(p, cfg: ModelConfig, x, positions, *, causal=True,
               window=None, kv=None, kv_positions=None, positions3=None):
    """Full-sequence attention (train / prefill). Optional cross-attention
    via `kv` (encoder output). Returns (out, (k, v)) so callers can build
    decode caches."""
    B, S, D = x.shape
    H, K, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = matmul(x, p["wq"]).reshape(B, S, H, hd)
    src = x if kv is None else kv
    Sk = src.shape[1]
    k = matmul(src, p["wk"]).reshape(B, Sk, K, hd)
    v = matmul(src, p["wv"]).reshape(B, Sk, K, hd)
    if cfg.qk_norm:
        q = rms_norm(p["q_norm"], q, cfg.norm_eps)
        k = rms_norm(p["k_norm"], k, cfg.norm_eps)
    kpos = kv_positions if kv_positions is not None else positions
    if kv is None:  # self-attention → rotary
        if positions3 is not None and cfg.mrope_sections:
            q = apply_mrope(q, positions3, cfg.rope_theta, cfg.mrope_sections)
            k = apply_mrope(k, positions3, cfg.rope_theta, cfg.mrope_sections)
        else:
            q = apply_rope(q, positions, cfg.rope_theta)
            k = apply_rope(k, kpos, cfg.rope_theta)
    if S * Sk > ATTN_CHUNK_THRESHOLD * ATTN_CHUNK_THRESHOLD // 64:
        out = chunked_attention(q, k, v, positions, kpos,
                                causal and kv is None, window)
    else:
        bias = _mask_bias(positions, kpos, causal and kv is None, window)
        out = attention_scores(q, k, v, bias)
    return matmul(out.reshape(B, S, H * hd), p["wo"]), (k, v)


def attn_decode(p, cfg: ModelConfig, x, pos, k_cache, v_cache, *,
                window=None, positions3=None):
    """Single-token decode against a (possibly seq-sharded) KV cache.

    x [B,1,D]; pos [B] current position; caches [B,S,K,hd].
    Returns (out, k_cache, v_cache)."""
    B, _, D = x.shape
    H, K, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    S = k_cache.shape[1]
    q = matmul(x, p["wq"]).reshape(B, 1, H, hd)
    k = matmul(x, p["wk"]).reshape(B, 1, K, hd)
    v = matmul(x, p["wv"]).reshape(B, 1, K, hd)
    if cfg.qk_norm:
        q = rms_norm(p["q_norm"], q, cfg.norm_eps)
        k = rms_norm(p["k_norm"], k, cfg.norm_eps)
    if positions3 is not None and cfg.mrope_sections:
        q = apply_mrope(q, positions3, cfg.rope_theta, cfg.mrope_sections)
        k = apply_mrope(k, positions3, cfg.rope_theta, cfg.mrope_sections)
    else:
        q = apply_rope(q, pos[:, None], cfg.rope_theta)
        k = apply_rope(k, pos[:, None], cfg.rope_theta)
    # write the new KV at slot pos (ring for windowed caches).
    # B==1 (long-context) caches shard their seq axis across the whole
    # mesh; a batched-index scatter there triggers GSPMD's "involuntary
    # full rematerialization" (an all-gather of the entire cache per
    # token).  The elementwise masked update is resharding-free and
    # SPMD-partitions natively (§Perf B1: −99.9% collective bytes).
    slot = pos if window is None else pos % S
    if B == 1 or _MASK_KV_UPDATE:
        sel = (jnp.arange(S)[None, :] == slot[:, None])[..., None, None]
        k_cache = jnp.where(sel, k.astype(k_cache.dtype), k_cache)
        v_cache = jnp.where(sel, v.astype(v_cache.dtype), v_cache)
    else:
        bidx = jnp.arange(B)
        k_cache = k_cache.at[bidx, slot].set(k[:, 0])
        v_cache = v_cache.at[bidx, slot].set(v[:, 0])
    kpos = jnp.arange(S)[None, :]  # logical positions of cache slots
    if window is not None:
        # ring layout: slot i holds the unique position p in
        # [max(0, pos+1-S), pos] with p % S == i
        ring_base = jnp.maximum(pos + 1 - S, 0)[:, None]
        kpos = ring_base + (kpos - ring_base) % S
    valid = (kpos <= pos[:, None]) & (kpos >= 0)
    if window is not None:
        valid = valid & (kpos > pos[:, None] - window)
    # [B,1,1,S] to broadcast against logits [B,K,G,S]
    bias = jnp.where(valid, 0.0, -1e30).astype(jnp.float32)[:, None, None, :]
    G = H // K
    qg = q.reshape(B, K, G, hd)
    logits = einsum_f32("bkgh,bskh->bkgs", qg, k_cache)
    logits = logits / jnp.sqrt(hd).astype(jnp.float32) + bias
    w = jax.nn.softmax(logits, axis=-1)
    out = einsum_f32("bkgs,bskh->bkgh", w.astype(v_cache.dtype), v_cache)
    out = out.reshape(B, 1, H * hd).astype(x.dtype)
    return matmul(out, p["wo"]), k_cache, v_cache


# ---------------------------------------------------------------- MLP


def mlp_init(key, cfg: ModelConfig, d_ff: Optional[int] = None):
    D, F = cfg.d_model, d_ff or cfg.d_ff
    dt = dtype_of(cfg)
    ks = jax.random.split(key, 3)
    return {
        "wi": dense_init(ks[0], (D, F), dtype=dt),
        "wg": dense_init(ks[1], (D, F), dtype=dt),
        "wo": dense_init(ks[2], (F, D), dtype=dt),
    }


def mlp_apply(p, x):
    return matmul(jax.nn.silu(matmul(x, p["wg"]).astype(jnp.float32))
                  .astype(x.dtype) * matmul(x, p["wi"]), p["wo"])


# ---------------------------------------------------------------- embeddings


def embed_init(key, cfg: ModelConfig):
    V, D = cfg.padded_vocab, cfg.d_model
    dt = dtype_of(cfg)
    ks = jax.random.split(key, 2)
    p = {"tok": dense_init(ks[0], (V, D), dtype=dt)}
    if not cfg.tie_embeddings:
        p["head"] = dense_init(ks[1], (D, V), dtype=dt)
    return p


def embed_apply(p, tokens):
    return jnp.take(p["tok"], tokens, axis=0)


def logits_apply(p, x):
    w = p.get("head")
    if w is None:
        w = p["tok"].T
    return einsum_f32("...d,dv->...v", x, w)
