"""Decoder-only transformer (dense family): scan-over-layers, remat-able.

Covers qwen3-32b (qk_norm), phi3-medium-14b, granite-3-2b, yi-6b, the
mixtral attention backbone (SWA window) and qwen2-vl (M-RoPE via
positions3).  MoE swaps the FFN through `ffn_apply` (repro.models.moe).
"""
from __future__ import annotations

from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L


def block_init(key, cfg: ModelConfig, ffn_init: Callable):
    k1, k2 = jax.random.split(key)
    return {
        "ln1": L.rms_norm_init(cfg.d_model),
        "attn": L.attn_init(k1, cfg),
        "ln2": L.rms_norm_init(cfg.d_model),
        "ffn": ffn_init(k2, cfg),
    }


def init(key, cfg: ModelConfig, ffn_init: Callable = L.mlp_init):
    ke, kl, kf = jax.random.split(key, 3)
    lkeys = jax.random.split(kl, cfg.n_layers)
    stacked = jax.vmap(lambda k: block_init(k, cfg, ffn_init))(lkeys)
    return {
        "embed": L.embed_init(ke, cfg),
        "layers": stacked,
        "ln_f": L.rms_norm_init(cfg.d_model),
    }


def block_apply(lp, cfg: ModelConfig, ffn_apply: Callable, x, positions,
                positions3=None, constrain=lambda t, kind: t):
    h = L.rms_norm(lp["ln1"], x, cfg.norm_eps)
    a, _ = L.attn_apply(lp["attn"], cfg, h, positions,
                        window=cfg.window, positions3=positions3)
    x = constrain(x + a, "act")
    h = L.rms_norm(lp["ln2"], x, cfg.norm_eps)
    x = constrain(x + ffn_apply(lp["ffn"], h), "act")
    return x


def forward(params, cfg: ModelConfig, tokens, *, positions=None,
            positions3=None, input_embeds=None,
            ffn_apply: Callable = lambda p, x: L.mlp_apply(p, x),
            constrain=lambda t, kind: t, remat: bool = True):
    """Full-sequence forward → logits [B,S,V] (fp32).

    `input_embeds` [B,P,D] (vlm/audio stubs) override the first P embedding
    rows.  `constrain` applies sharding constraints (set by the launcher).
    """
    B, S = tokens.shape
    x = L.embed_apply(params["embed"], tokens)
    if input_embeds is not None:
        P = input_embeds.shape[1]
        x = jnp.concatenate([input_embeds.astype(x.dtype), x[:, P:]], axis=1)
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
    x = constrain(x, "act")

    body = partial(block_apply, cfg=cfg, ffn_apply=ffn_apply,
                   positions=positions, positions3=positions3,
                   constrain=constrain)

    def scan_fn(x, lp):
        return body(lp, x=x), ()

    if remat:
        scan_fn = jax.checkpoint(
            scan_fn, policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = jax.lax.scan(scan_fn, x, params["layers"])
    x = L.rms_norm(params["ln_f"], x, cfg.norm_eps)
    return L.logits_apply(params["embed"], x)


# ---------------------------------------------------------------- decode


def init_cache(cfg: ModelConfig, batch: int, seq_len: int,
               dtype=jnp.bfloat16):
    """KV cache [L,B,S,K,hd] ×2. SWA archs keep a ring of `window` slots."""
    S = min(seq_len, cfg.window) if cfg.window else seq_len
    shape = (cfg.n_layers, batch, S, cfg.n_kv_heads, cfg.hd)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def prefill(params, cfg: ModelConfig, tokens, *, positions3=None,
            input_embeds=None, ffn_apply=lambda p, x: L.mlp_apply(p, x),
            constrain=lambda t, kind: t):
    """Forward pass that also materializes the KV cache (inference prefill).
    Returns (logits, cache)."""
    B, S = tokens.shape
    x = L.embed_apply(params["embed"], tokens)
    if input_embeds is not None:
        P = input_embeds.shape[1]
        x = jnp.concatenate([input_embeds.astype(x.dtype), x[:, P:]], axis=1)
    positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
    x = constrain(x, "act")

    def scan_fn(x, lp):
        h = L.rms_norm(lp["ln1"], x, cfg.norm_eps)
        a, (k, v) = L.attn_apply(lp["attn"], cfg, h, positions,
                                 window=cfg.window, positions3=positions3)
        x = constrain(x + a, "act")
        h = L.rms_norm(lp["ln2"], x, cfg.norm_eps)
        x = constrain(x + ffn_apply(lp["ffn"], h), "act")
        if cfg.window and cfg.window < S:
            k, v = k[:, -cfg.window:], v[:, -cfg.window:]
        return x, (constrain(k, "kv"), constrain(v, "kv"))

    x, (ks, vs) = jax.lax.scan(scan_fn, x, params["layers"])
    x = L.rms_norm(params["ln_f"], x, cfg.norm_eps)
    logits = L.logits_apply(params["embed"], x[:, -1:])
    return logits, {"k": ks, "v": vs}


def decode_step(params, cfg: ModelConfig, cache, tokens, pos, *,
                positions3=None,
                ffn_apply=lambda p, x: L.mlp_apply(p, x),
                constrain=lambda t, kind: t):
    """One decode step. tokens [B,1]; pos [B]. Returns (logits, cache)."""
    x = L.embed_apply(params["embed"], tokens)
    x = constrain(x, "act")

    def scan_fn(x, inp):
        lp, kc, vc = inp
        h = L.rms_norm(lp["ln1"], x, cfg.norm_eps)
        a, kc, vc = L.attn_decode(lp["attn"], cfg, h, pos, kc, vc,
                                  window=cfg.window, positions3=positions3)
        x = constrain(x + a, "act")
        h = L.rms_norm(lp["ln2"], x, cfg.norm_eps)
        x = constrain(x + ffn_apply(lp["ffn"], h), "act")
        return x, (constrain(kc, "kv"), constrain(vc, "kv"))

    x, (ks, vs) = jax.lax.scan(scan_fn, x, (params["layers"],
                                            cache["k"], cache["v"]))
    x = L.rms_norm(params["ln_f"], x, cfg.norm_eps)
    logits = L.logits_apply(params["embed"], x)
    return logits, {"k": ks, "v": vs}
