"""Mixture-of-Experts FFN (mixtral-8x7b, granite-moe-1b-a400m).

Top-k routing with grouped capacity-based dispatch (Switch/Mesh-TF style):
tokens are split into fixed-size groups of M=512 so the one-hot dispatch
tensor is [G, M, E, C] with C = M·k/E·cf — total memory ∝ T·k·cf
regardless of E, and the group axis shards with the data axis.  Experts
run as one batched einsum so the expert dim can be TP/EP-sharded.
Overflowing tokens drop (capacity factor, standard practice); §Perf
discusses the sort-based dropless alternative.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L

GROUP = 512  # tokens per routing group


def moe_init(key, cfg: ModelConfig):
    E, D, F = cfg.n_experts, cfg.d_model, cfg.d_ff
    dt = L.dtype_of(cfg)
    ks = jax.random.split(key, 4)
    return {
        "router": L.dense_init(ks[0], (D, E), dtype=jnp.float32),
        "wi": L.dense_init(ks[1], (E, D, F), in_axis=1, dtype=dt),
        "wg": L.dense_init(ks[2], (E, D, F), in_axis=1, dtype=dt),
        "wo": L.dense_init(ks[3], (E, F, D), in_axis=1, dtype=dt),
    }


def moe_apply(p, x, cfg: ModelConfig):
    """x [B,S,D] → [B,S,D]."""
    B, S, D = x.shape
    E, k = cfg.n_experts, cfg.top_k
    M = min(GROUP, B * S)
    T = B * S
    assert T % M == 0, (B, S, M)
    G = T // M
    C = max(int(cfg.capacity_factor * M * k / E), 1)
    C = min(C, M)

    xg = x.reshape(G, M, D)
    gates = jax.nn.softmax(
        L.einsum_f32("gmd,de->gme", xg.astype(jnp.float32), p["router"]), -1)
    topv, topi = jax.lax.top_k(gates, k)                   # [G,M,k]
    topv = topv / jnp.maximum(topv.sum(-1, keepdims=True), 1e-9)

    onehot = jax.nn.one_hot(topi, E, dtype=jnp.float32)    # [G,M,k,E]
    # position of each (token, choice) within its expert's capacity —
    # earlier tokens (and earlier choices) win slots
    pos = jnp.cumsum(onehot.reshape(G, M * k, E), axis=1)
    pos = pos.reshape(G, M, k, E) * onehot - 1.0           # [G,M,k,E]
    # collapse the choice axis (an expert appears at most once per token)
    pos_e = (pos * onehot).sum(2)                          # [G,M,E]
    sel_e = onehot.sum(2)                                  # [G,M,E] ∈ {0,1}
    gate_e = (topv[..., None] * onehot).sum(2)             # [G,M,E]
    keep_e = (sel_e > 0) & (pos_e < C)
    slot = jnp.where(keep_e, pos_e, C).astype(jnp.int32)
    disp = jax.nn.one_hot(slot, C + 1, dtype=jnp.float32)[..., :C]
    disp = disp * keep_e[..., None]                        # [G,M,E,C]

    xin = jnp.einsum("gmec,gmd->gecd", disp.astype(x.dtype), xg).astype(x.dtype)
    hg = L.einsum_f32("gecd,edf->gecf", xin, p["wg"])
    hi = L.einsum_f32("gecd,edf->gecf", xin, p["wi"]).astype(x.dtype)
    h = jax.nn.silu(hg).astype(x.dtype) * hi
    out = L.einsum_f32("gecf,efd->gecd", h, p["wo"]).astype(x.dtype)
    comb = disp * gate_e[..., None]                        # [G,M,E,C]
    y = L.einsum_f32("gmec,gecd->gmd", comb.astype(x.dtype), out)
    return y.reshape(B, S, D).astype(x.dtype)


def aux_loss(p, x, cfg: ModelConfig):
    """Load-balancing auxiliary loss (Switch): E·Σ_e f_e·P_e."""
    gates = jax.nn.softmax(
        jnp.einsum("bsd,de->bse", x.astype(jnp.float32), p["router"]), -1)
    top1 = jnp.argmax(gates, -1)
    f = jnp.mean(jax.nn.one_hot(top1, cfg.n_experts, dtype=jnp.float32),
                 axis=(0, 1))
    P = jnp.mean(gates, axis=(0, 1))
    return cfg.n_experts * jnp.sum(f * P)
