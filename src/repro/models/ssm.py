"""Mamba2 (state-space duality / SSD) — arXiv:2405.21060.

Chunked SSD forward: intra-chunk attention-like einsums + inter-chunk
state recurrence via ``lax.associative_scan`` (parallel prefix on TPU —
a deliberate TPU-idiomatic choice over the sequential CUDA chunk scan).
Heads are kept factored as (groups g, repeats r) so B/C never expand to
the full head dim.  A Pallas kernel for the intra-chunk block lives in
``repro.kernels.ssd_scan`` with this as its oracle-producing reference.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L


def ssm_init(key, cfg: ModelConfig):
    D = cfg.d_model
    di = cfg.d_inner
    G, N, H = cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
    conv_dim = di + 2 * G * N
    dt = L.dtype_of(cfg)
    ks = jax.random.split(key, 4)
    return {
        "in_proj": L.dense_init(ks[0], (D, 2 * di + 2 * G * N + H), dtype=dt),
        "conv_w": (jax.random.normal(ks[1], (cfg.ssm_conv, conv_dim),
                                     jnp.float32) * 0.1).astype(dt),
        "conv_b": jnp.zeros((conv_dim,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "A_log": jnp.zeros((H,), jnp.float32),
        "Dskip": jnp.ones((H,), jnp.float32),
        "norm": L.rms_norm_init(di),
        "out_proj": L.dense_init(ks[3], (di, D), dtype=dt),
    }


def _causal_conv(x, w, b):
    """Depthwise causal conv1d. x [B,S,C]; w [K,C]."""
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    y = sum(xp[:, i:i + x.shape[1], :] * w[i] for i in range(K))
    return y + b.astype(y.dtype)


def ssd_chunked(x, dtv, A, B, C, chunk: int, state0=None):
    """SSD over a full sequence.

    x [b,s,g,r,p]; dtv [b,s,g,r]; A [g,r]; B,C [b,s,g,n].
    Returns (y [b,s,g,r,p], final_state [b,g,r,n,p]).
    """
    b, s, g, r, p = x.shape
    n = B.shape[-1]
    assert s % chunk == 0, (s, chunk)
    nc, q = s // chunk, chunk
    xb = x.reshape(b, nc, q, g, r, p)
    dtb = dtv.reshape(b, nc, q, g, r).astype(jnp.float32)
    Bb = B.reshape(b, nc, q, g, n)
    Cb = C.reshape(b, nc, q, g, n)

    dA = dtb * A                                  # [b,nc,q,g,r] (A<0)
    cs = jnp.cumsum(dA, axis=2)                   # within-chunk cumsum
    # intra-chunk ("diagonal block"): M_ij = C_i·B_j · exp(cs_i-cs_j) · dt_j
    CB = L.einsum_f32("bcign,bcjgn->bcgij", Cb, Bb)
    ci = cs[:, :, :, :, :, None]                  # [b,nc,q,g,r,1]
    cj = jnp.moveaxis(cs, 2, -1)[:, :, None]      # [b,nc,1,g,r,q]
    Ldec = jnp.exp(jnp.clip(ci - cj, -60.0, 0.0))
    causal = jnp.tril(jnp.ones((q, q), jnp.bool_))
    Ldec = Ldec * causal[None, None, :, None, None, :]
    dtj = jnp.moveaxis(dtb, 2, -1)[:, :, None]    # [b,nc,1,g,r,q]
    # CB [b,nc,g,i,j] → broadcast over r: [b,nc,i,g,1,j]
    CBr = jnp.moveaxis(CB, 2, 3)[:, :, :, :, None, :]
    # bf16 for the O(q²·heads) temporaries: halves the dominant HBM
    # traffic of the intra-chunk block (§Perf C2); exp stays fp32.
    W = (CBr.astype(x.dtype) * Ldec.astype(x.dtype)
         * dtj.astype(x.dtype))                   # [b,nc,i,g,r,j]
    xj = jnp.moveaxis(xb, 2, -1)                  # [b,nc,g,r,p,j]
    y_intra = L.einsum_f32("bcigrj,bcgrpj->bcigrp", W, xj)

    # chunk-local end states: S_c = Σ_j exp(cs_last - cs_j)·dt_j·B_j ⊗ x_j
    decay_end = jnp.exp(jnp.clip(cs[:, :, -1:, :, :] - cs, -60.0, 0.0))
    wght = (decay_end * dtb).astype(x.dtype)      # [b,nc,q,g,r]
    S_loc = L.einsum_f32("bcqgn,bcqgr,bcqgrp->bcgrnp", Bb, wght, xb)
    chunk_decay = jnp.exp(jnp.clip(jnp.sum(dA, axis=2), -60.0, 0.0))

    # inter-chunk recurrence via parallel prefix (associative):
    #   (d2, S2) ∘ (d1, S1) = (d1·d2, S1·d2 + S2)
    def combine(a, bb):
        d1, s1 = a
        d2, s2 = bb
        return d1 * d2, s1 * d2[..., None, None] + s2
    if state0 is not None:
        S_loc = S_loc.at[:, 0].add(
            state0.astype(jnp.float32) * chunk_decay[:, 0][..., None, None])
    dacc, Sacc = jax.lax.associative_scan(
        combine, (chunk_decay, S_loc), axis=1)    # inclusive prefix
    # states *entering* chunk c = Sacc[c-1] (zero for c=0)
    S_prev = jnp.concatenate(
        [jnp.zeros_like(Sacc[:, :1]), Sacc[:, :-1]], axis=1)
    y_inter = L.einsum_f32("bcqgn,bcgrnp->bcqgrp", Cb,
                         S_prev.astype(x.dtype))
    y_inter = y_inter * jnp.exp(jnp.clip(cs, -60.0, 0.0))[..., None]
    y = (y_intra + y_inter).reshape(b, s, g, r, p)
    return y.astype(x.dtype), Sacc[:, -1].astype(x.dtype)


def ssm_apply(p, cfg: ModelConfig, u, state=None, return_state=False):
    """Full-sequence mamba2 mixer. u [B,S,D] → [B,S,D]."""
    B_, S, D = u.shape
    di, G, N, H = cfg.d_inner, cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
    r = H // G
    pdim = cfg.ssm_headdim
    zxbcdt = L.matmul(u, p["in_proj"])
    z, xBC, dtv = jnp.split(zxbcdt, [di, 2 * di + 2 * G * N], axis=-1)
    xBC = jax.nn.silu(
        _causal_conv(xBC, p["conv_w"], p["conv_b"]).astype(jnp.float32)
    ).astype(u.dtype)
    x, Bmat, Cmat = jnp.split(xBC, [di, di + G * N], axis=-1)
    x = x.reshape(B_, S, G, r, pdim)
    Bmat = Bmat.reshape(B_, S, G, N)
    Cmat = Cmat.reshape(B_, S, G, N)
    dtv = jax.nn.softplus(dtv.astype(jnp.float32) + p["dt_bias"])
    dtv = dtv.reshape(B_, S, G, r)
    A = -jnp.exp(p["A_log"]).reshape(G, r)
    y, fstate = ssd_chunked(x, dtv, A, Bmat, Cmat, cfg.ssm_chunk,
                            state0=state)
    y = y + (p["Dskip"].reshape(G, r)[None, None, :, :, None]
             * x.astype(jnp.float32)).astype(y.dtype)
    y = y.reshape(B_, S, di)
    y = L.rms_norm(p["norm"], y * jax.nn.silu(z.astype(jnp.float32)
                                              ).astype(y.dtype), cfg.norm_eps)
    out = L.matmul(y, p["out_proj"])
    if return_state:
        return out, fstate
    return out


# ---------------------------------------------------------------- decode


def ssm_init_cache(cfg: ModelConfig, batch: int, dtype=jnp.bfloat16):
    G, r = cfg.ssm_groups, cfg.ssm_heads // cfg.ssm_groups
    conv_dim = cfg.d_inner + 2 * G * cfg.ssm_state
    return {
        "state": jnp.zeros((batch, G, r, cfg.ssm_state, cfg.ssm_headdim),
                           dtype),
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, conv_dim), dtype),
    }


def ssm_decode_step(p, cfg: ModelConfig, cache, u):
    """u [B,1,D] → (out [B,1,D], cache)."""
    B_, _, D = u.shape
    di, G, N, H = cfg.d_inner, cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
    r = H // G
    pdim = cfg.ssm_headdim
    zxbcdt = L.matmul(u, p["in_proj"])[:, 0]
    z, xBC, dtv = jnp.split(zxbcdt, [di, 2 * di + 2 * G * N], axis=-1)
    # conv over (cached K-1 inputs, current)
    hist = jnp.concatenate([cache["conv"], xBC[:, None, :]], axis=1)
    w = p["conv_w"]
    xBC_c = jnp.sum(hist * w[None], axis=1) + p["conv_b"].astype(u.dtype)
    xBC_c = jax.nn.silu(xBC_c.astype(jnp.float32)).astype(u.dtype)
    x, Bmat, Cmat = jnp.split(xBC_c, [di, di + G * N], axis=-1)
    x = x.reshape(B_, G, r, pdim)
    Bmat = Bmat.reshape(B_, G, N)
    Cmat = Cmat.reshape(B_, G, N)
    dtv = jax.nn.softplus(dtv.astype(jnp.float32) + p["dt_bias"])
    dtv = dtv.reshape(B_, G, r)
    A = -jnp.exp(p["A_log"]).reshape(G, r)
    dA = jnp.exp(dtv * A)                                  # [B,G,r]
    upd = jnp.einsum("bgn,bgr,bgrp->bgrnp", Bmat.astype(jnp.float32),
                     dtv, x.astype(jnp.float32))
    state = (cache["state"].astype(jnp.float32)
             * dA[..., None, None] + upd)
    y = jnp.einsum("bgn,bgrnp->bgrp", Cmat.astype(jnp.float32), state)
    y = y + p["Dskip"].reshape(G, r)[None, :, :, None] * x.astype(jnp.float32)
    y = y.reshape(B_, di).astype(u.dtype)
    y = L.rms_norm(p["norm"], y * jax.nn.silu(z.astype(jnp.float32)
                                              ).astype(u.dtype), cfg.norm_eps)
    out = L.matmul(y[:, None, :], p["out_proj"])
    cache = {
        "state": state.astype(cache["state"].dtype),
        "conv": hist[:, 1:],
    }
    return out, cache


# ---------------------------------------------------------------- blocks


def block_init(key, cfg: ModelConfig):
    return {"ln": L.rms_norm_init(cfg.d_model), "mixer": ssm_init(key, cfg)}


def init(key, cfg: ModelConfig):
    ke, kl = jax.random.split(key)
    lkeys = jax.random.split(kl, cfg.n_layers)
    return {
        "embed": L.embed_init(ke, cfg),
        "layers": jax.vmap(lambda k: block_init(k, cfg))(lkeys),
        "ln_f": L.rms_norm_init(cfg.d_model),
    }


def forward(params, cfg: ModelConfig, tokens, constrain=lambda t, k: t,
            remat: bool = True):
    x = L.embed_apply(params["embed"], tokens)
    x = constrain(x, "act")

    def scan_fn(x, lp):
        h = L.rms_norm(lp["ln"], x, cfg.norm_eps)
        x = constrain(x + ssm_apply(lp["mixer"], cfg, h), "act")
        return x, ()

    if remat:
        scan_fn = jax.checkpoint(
            scan_fn,
            policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = jax.lax.scan(scan_fn, x, params["layers"])
    x = L.rms_norm(params["ln_f"], x, cfg.norm_eps)
    return L.logits_apply(params["embed"], x)


def init_cache(cfg: ModelConfig, batch: int, seq_len: int,
               dtype=jnp.bfloat16):
    del seq_len  # O(1) state — the whole point of the SSM family
    c = ssm_init_cache(cfg, batch, dtype)
    return {
        "state": jnp.zeros((cfg.n_layers,) + c["state"].shape, dtype),
        "conv": jnp.zeros((cfg.n_layers,) + c["conv"].shape, dtype),
    }


def decode_step(params, cfg: ModelConfig, cache, tokens, pos,
                constrain=lambda t, k: t):
    del pos
    x = L.embed_apply(params["embed"], tokens)
    x = constrain(x, "act")

    def scan_fn(x, inp):
        lp, st, cv = inp
        h = L.rms_norm(lp["ln"], x, cfg.norm_eps)
        out, c2 = ssm_decode_step(lp["mixer"], cfg, {"state": st, "conv": cv},
                                  h)
        x = constrain(x + out, "act")
        return x, (c2["state"], c2["conv"])

    x, (sts, cvs) = jax.lax.scan(
        scan_fn, x, (params["layers"], cache["state"], cache["conv"]))
    x = L.rms_norm(params["ln_f"], x, cfg.norm_eps)
    return L.logits_apply(params["embed"], x), {"state": sts, "conv": cvs}
