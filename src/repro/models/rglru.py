"""RecurrentGemma / Griffin hybrid (arXiv:2402.19427).

Repeating pattern (rec, rec, attn): two RG-LRU recurrent blocks followed by
one local-attention block (window 2048, MQA).  The linear recurrence
h_t = a_t·h_{t-1} + sqrt(1-a_t²)·(i_t⊙x_t) is associative, so the full
sequence runs as ``lax.associative_scan`` (parallel prefix — TPU-idiomatic
replacement for the sequential CUDA scan).  Gates use block-diagonal
projections (16 blocks) as in the reference implementation.

Layers are heterogeneous, so the stack is scanned as superblocks of the
repeating unit plus an explicit tail (26 = 8×3 + 2).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L

N_GATE_BLOCKS = 16
LRU_C = 8.0


# ---------------------------------------------------------------- RG-LRU


def _bdiag_init(key, w: int, dtype):
    nb = N_GATE_BLOCKS
    return (jax.random.normal(key, (nb, w // nb, w // nb), jnp.float32)
            / jnp.sqrt(w // nb)).astype(dtype)


def _bdiag_apply(wt, x):
    nb = wt.shape[0]
    B_, S, W = x.shape
    xb = x.reshape(B_, S, nb, W // nb)
    y = L.einsum_f32("bsnw,nwv->bsnv", xb, wt)
    return y.reshape(B_, S, W)


def rglru_init(key, cfg: ModelConfig):
    W = cfg.lru_width or cfg.d_model
    dt = L.dtype_of(cfg)
    ks = jax.random.split(key, 3)
    # Λ init so a ∈ (0.9, 0.999) at r=1 (paper init)
    lam = jax.random.uniform(ks[2], (W,), jnp.float32, 0.9, 0.999)
    a_param = jnp.log(jnp.expm1(-jnp.log(lam) / LRU_C))  # inv-softplus
    return {
        "wa": _bdiag_init(ks[0], W, dt),
        "ba": jnp.zeros((W,), jnp.float32),
        "wx": _bdiag_init(ks[1], W, dt),
        "bx": jnp.zeros((W,), jnp.float32),
        "a_param": a_param,
    }


def rglru_apply(p, x, h0=None):
    """x [B,S,W] → (y [B,S,W], h_last [B,W]) via parallel prefix scan."""
    r = jax.nn.sigmoid(_bdiag_apply(p["wa"], x) + p["ba"])
    i = jax.nn.sigmoid(_bdiag_apply(p["wx"], x) + p["bx"])
    log_a = -LRU_C * jax.nn.softplus(p["a_param"]) * r      # [B,S,W] fp32
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) \
        * i * x.astype(jnp.float32)

    if h0 is not None:
        gated = gated.at[:, 0].add(a[:, 0] * h0.astype(jnp.float32))

    def combine(c1, c2):
        a1, u1 = c1
        a2, u2 = c2
        return a1 * a2, u1 * a2 + u2

    _, h = jax.lax.associative_scan(combine, (a, gated), axis=1)
    return h.astype(x.dtype), h[:, -1]


def rglru_step(p, x_t, h):
    """One decode step. x_t [B,W]; h [B,W]."""
    xs = x_t[:, None, :]
    r = jax.nn.sigmoid(_bdiag_apply(p["wa"], xs) + p["ba"])[:, 0]
    i = jax.nn.sigmoid(_bdiag_apply(p["wx"], xs) + p["bx"])[:, 0]
    log_a = -LRU_C * jax.nn.softplus(p["a_param"]) * r
    a = jnp.exp(log_a)
    h2 = a * h.astype(jnp.float32) + jnp.sqrt(
        jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) \
        * i * x_t.astype(jnp.float32)
    return h2.astype(x_t.dtype), h2.astype(x_t.dtype)


# ---------------------------------------------------------------- blocks


def rec_block_init(key, cfg: ModelConfig):
    W = cfg.lru_width or cfg.d_model
    D = cfg.d_model
    dt = L.dtype_of(cfg)
    ks = jax.random.split(key, 5)
    return {
        "ln1": L.rms_norm_init(D),
        "wxin": L.dense_init(ks[0], (D, W), dtype=dt),
        "wgate": L.dense_init(ks[1], (D, W), dtype=dt),
        "conv_w": (jax.random.normal(ks[2], (4, W), jnp.float32) * 0.1
                   ).astype(dt),
        "conv_b": jnp.zeros((W,), jnp.float32),
        "lru": rglru_init(ks[3], cfg),
        "wout": L.dense_init(ks[4], (W, D), dtype=dt),
        "ln2": L.rms_norm_init(D),
        "mlp": L.mlp_init(jax.random.split(ks[4])[0], cfg),
    }


def _conv4(x, w, b):
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    return sum(xp[:, i:i + x.shape[1], :] * w[i] for i in range(K)) \
        + b.astype(x.dtype)


def rec_block_apply(lp, cfg, x, h0=None, conv0=None, return_state=False):
    h = L.rms_norm(lp["ln1"], x, cfg.norm_eps)
    xin = L.matmul(h, lp["wxin"])
    gate = jax.nn.gelu(L.matmul(h, lp["wgate"]).astype(jnp.float32)
                       ).astype(x.dtype)
    if conv0 is not None:  # decode: stitch conv history
        xin_full = jnp.concatenate([conv0, xin], axis=1)
        conv_out = _conv4(xin_full, lp["conv_w"], lp["conv_b"])
        conv_out = conv_out[:, conv0.shape[1]:]
        new_conv = xin_full[:, -3:]
    else:
        conv_out = _conv4(xin, lp["conv_w"], lp["conv_b"])
        new_conv = xin[:, -3:]
    y, h_last = rglru_apply(lp["lru"], conv_out, h0=h0)
    x = x + L.matmul(y * gate, lp["wout"])
    h2 = L.rms_norm(lp["ln2"], x, cfg.norm_eps)
    x = x + L.mlp_apply(lp["mlp"], h2)
    if return_state:
        return x, (h_last, new_conv)
    return x


def attn_block_init(key, cfg: ModelConfig):
    k1, k2 = jax.random.split(key)
    return {
        "ln1": L.rms_norm_init(cfg.d_model),
        "attn": L.attn_init(k1, cfg),
        "ln2": L.rms_norm_init(cfg.d_model),
        "mlp": L.mlp_init(k2, cfg),
    }


def attn_block_apply(lp, cfg, x, positions, return_kv=False):
    h = L.rms_norm(lp["ln1"], x, cfg.norm_eps)
    a, kv = L.attn_apply(lp["attn"], cfg, h, positions,
                         window=cfg.local_window)
    x = x + a
    h = L.rms_norm(lp["ln2"], x, cfg.norm_eps)
    x = x + L.mlp_apply(lp["mlp"], h)
    if return_kv:
        return x, kv
    return x


# ------------------------------------------------------------ full model


def _layout(cfg: ModelConfig):
    P = len(cfg.block_pattern)          # 3: (rec, rec, attn)
    n_super = cfg.n_layers // P
    tail = cfg.n_layers - n_super * P   # leading-pattern remainder
    return n_super, tail


def init(key, cfg: ModelConfig):
    n_super, tail = _layout(cfg)
    ke, ks_, kt = jax.random.split(key, 3)
    skeys = jax.random.split(ks_, n_super)

    def super_init(k):
        k1, k2, k3 = jax.random.split(k, 3)
        return {
            "rec1": rec_block_init(k1, cfg),
            "rec2": rec_block_init(k2, cfg),
            "attn": attn_block_init(k3, cfg),
        }

    params = {
        "embed": L.embed_init(ke, cfg),
        "super": jax.vmap(super_init)(skeys),
        "ln_f": L.rms_norm_init(cfg.d_model),
    }
    if tail:
        tkeys = jax.random.split(kt, tail)
        params["tail"] = jax.vmap(lambda k: rec_block_init(k, cfg))(tkeys)
    return params


def forward(params, cfg: ModelConfig, tokens, constrain=lambda t, k: t,
            remat: bool = True):
    B_, S = tokens.shape
    x = L.embed_apply(params["embed"], tokens)
    x = constrain(x, "act")
    positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B_, S))

    def scan_fn(x, lp):
        x = rec_block_apply(lp["rec1"], cfg, x)
        x = rec_block_apply(lp["rec2"], cfg, x)
        x = constrain(attn_block_apply(lp["attn"], cfg, x, positions), "act")
        return x, ()

    if remat:
        scan_fn = jax.checkpoint(
            scan_fn,
            policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = jax.lax.scan(scan_fn, x, params["super"])
    if "tail" in params:
        def tail_fn(x, lp):
            return constrain(rec_block_apply(lp, cfg, x), "act"), ()
        if remat:
            tail_fn = jax.checkpoint(tail_fn)
        x, _ = jax.lax.scan(tail_fn, x, params["tail"])
    x = L.rms_norm(params["ln_f"], x, cfg.norm_eps)
    return L.logits_apply(params["embed"], x)


def init_cache(cfg: ModelConfig, batch: int, seq_len: int,
               dtype=jnp.bfloat16):
    n_super, tail = _layout(cfg)
    W = cfg.lru_width or cfg.d_model
    Wnd = min(cfg.local_window, seq_len)
    c = {
        "h1": jnp.zeros((n_super, batch, W), dtype),
        "c1": jnp.zeros((n_super, batch, 3, W), dtype),
        "h2": jnp.zeros((n_super, batch, W), dtype),
        "c2": jnp.zeros((n_super, batch, 3, W), dtype),
        "k": jnp.zeros((n_super, batch, Wnd, cfg.n_kv_heads, cfg.hd), dtype),
        "v": jnp.zeros((n_super, batch, Wnd, cfg.n_kv_heads, cfg.hd), dtype),
    }
    if tail:
        c["th"] = jnp.zeros((tail, batch, W), dtype)
        c["tc"] = jnp.zeros((tail, batch, 3, W), dtype)
    return c


def _rec_step(lp, cfg, x, h, conv):
    """Single-token recurrent block. x [B,1,D]."""
    hh = L.rms_norm(lp["ln1"], x, cfg.norm_eps)
    xin = L.matmul(hh, lp["wxin"])[:, 0]
    gate = jax.nn.gelu(L.matmul(hh, lp["wgate"]).astype(jnp.float32)
                       )[:, 0].astype(x.dtype)
    hist = jnp.concatenate([conv, xin[:, None]], axis=1)   # [B,4,W]
    w = lp["conv_w"]
    cv = jnp.sum(hist * w[None], axis=1) + lp["conv_b"].astype(x.dtype)
    y, h2 = rglru_step(lp["lru"], cv, h)
    x = x + L.matmul((y * gate)[:, None], lp["wout"])
    hh = L.rms_norm(lp["ln2"], x, cfg.norm_eps)
    x = x + L.mlp_apply(lp["mlp"], hh)
    return x, h2, hist[:, 1:]


def decode_step(params, cfg: ModelConfig, cache, tokens, pos,
                constrain=lambda t, k: t):
    x = L.embed_apply(params["embed"], tokens)
    x = constrain(x, "act")

    def scan_fn(x, inp):
        lp, h1, c1, h2, c2, kc, vc = inp
        x, h1, c1 = _rec_step(lp["rec1"], cfg, x, h1, c1)
        x, h2, c2 = _rec_step(lp["rec2"], cfg, x, h2, c2)
        hh = L.rms_norm(lp["attn"]["ln1"], x, cfg.norm_eps)
        a, kc, vc = L.attn_decode(lp["attn"]["attn"], cfg, hh, pos, kc, vc,
                                  window=cfg.local_window)
        x = x + a
        hh = L.rms_norm(lp["attn"]["ln2"], x, cfg.norm_eps)
        x = constrain(x + L.mlp_apply(lp["attn"]["mlp"], hh), "act")
        return x, (h1, c1, h2, c2, kc, vc)

    x, (h1, c1, h2, c2, kc, vc) = jax.lax.scan(
        scan_fn, x, (params["super"], cache["h1"], cache["c1"],
                     cache["h2"], cache["c2"], cache["k"], cache["v"]))
    out = dict(cache, h1=h1, c1=c1, h2=h2, c2=c2, k=kc, v=vc)
    if "tail" in params:
        def tail_fn(x, inp):
            lp, th, tc = inp
            x, th, tc = _rec_step(lp, cfg, x, th, tc)
            return x, (th, tc)
        x, (th, tc) = jax.lax.scan(
            tail_fn, x, (params["tail"], cache["th"], cache["tc"]))
        out["th"], out["tc"] = th, tc
    x = L.rms_norm(params["ln_f"], x, cfg.norm_eps)
    return L.logits_apply(params["embed"], x), out
