"""Encoder-decoder backbone (seamless-m4t-medium).

The audio frontend is a STUB per the assignment: ``input_specs`` provides
precomputed frame embeddings [B, S_src, D]; this module implements the
transformer backbone (12L bidirectional encoder + 12L causal decoder with
cross-attention) end to end.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L


def enc_block_init(key, cfg):
    k1, k2 = jax.random.split(key)
    return {
        "ln1": L.rms_norm_init(cfg.d_model),
        "attn": L.attn_init(k1, cfg),
        "ln2": L.rms_norm_init(cfg.d_model),
        "mlp": L.mlp_init(k2, cfg),
    }


def dec_block_init(key, cfg):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "ln1": L.rms_norm_init(cfg.d_model),
        "attn": L.attn_init(k1, cfg),
        "lnx": L.rms_norm_init(cfg.d_model),
        "cross": L.attn_init(k2, cfg),
        "ln2": L.rms_norm_init(cfg.d_model),
        "mlp": L.mlp_init(k3, cfg),
    }


def init(key, cfg: ModelConfig):
    ke, kenc, kdec = jax.random.split(key, 3)
    ekeys = jax.random.split(kenc, cfg.n_enc_layers)
    dkeys = jax.random.split(kdec, cfg.n_layers)
    return {
        "embed": L.embed_init(ke, cfg),
        "enc": jax.vmap(lambda k: enc_block_init(k, cfg))(ekeys),
        "enc_ln": L.rms_norm_init(cfg.d_model),
        "dec": jax.vmap(lambda k: dec_block_init(k, cfg))(dkeys),
        "ln_f": L.rms_norm_init(cfg.d_model),
    }


def encode(params, cfg: ModelConfig, src_embeds, constrain=lambda t, k: t,
           remat: bool = True):
    B_, Ss, _ = src_embeds.shape
    pos = jnp.broadcast_to(jnp.arange(Ss)[None, :], (B_, Ss))
    x = constrain(src_embeds, "act")

    def scan_fn(x, lp):
        h = L.rms_norm(lp["ln1"], x, cfg.norm_eps)
        a, _ = L.attn_apply(lp["attn"], cfg, h, pos, causal=False)
        x = constrain(x + a, "act")
        h = L.rms_norm(lp["ln2"], x, cfg.norm_eps)
        return constrain(x + L.mlp_apply(lp["mlp"], h), "act"), ()

    if remat:
        scan_fn = jax.checkpoint(
            scan_fn,
            policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = jax.lax.scan(scan_fn, x, params["enc"])
    return L.rms_norm(params["enc_ln"], x, cfg.norm_eps)


def forward(params, cfg: ModelConfig, tokens, src_embeds,
            constrain=lambda t, k: t, remat: bool = True):
    """Teacher-forced train/eval forward → decoder logits."""
    enc = encode(params, cfg, src_embeds, constrain, remat)
    B_, St = tokens.shape
    Ss = enc.shape[1]
    pos = jnp.broadcast_to(jnp.arange(St)[None, :], (B_, St))
    spos = jnp.broadcast_to(jnp.arange(Ss)[None, :], (B_, Ss))
    x = constrain(L.embed_apply(params["embed"], tokens), "act")

    def scan_fn(x, lp):
        h = L.rms_norm(lp["ln1"], x, cfg.norm_eps)
        a, _ = L.attn_apply(lp["attn"], cfg, h, pos)
        x = constrain(x + a, "act")
        h = L.rms_norm(lp["lnx"], x, cfg.norm_eps)
        a, _ = L.attn_apply(lp["cross"], cfg, h, pos, kv=enc,
                            kv_positions=spos)
        x = constrain(x + a, "act")
        h = L.rms_norm(lp["ln2"], x, cfg.norm_eps)
        return constrain(x + L.mlp_apply(lp["mlp"], h), "act"), ()

    if remat:
        scan_fn = jax.checkpoint(
            scan_fn,
            policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = jax.lax.scan(scan_fn, x, params["dec"])
    x = L.rms_norm(params["ln_f"], x, cfg.norm_eps)
    return L.logits_apply(params["embed"], x)


def init_cache(cfg: ModelConfig, batch: int, seq_len: int,
               dtype=jnp.bfloat16):
    Ld = cfg.n_layers
    K, hd = cfg.n_kv_heads, cfg.hd
    return {
        "k": jnp.zeros((Ld, batch, seq_len, K, hd), dtype),
        "v": jnp.zeros((Ld, batch, seq_len, K, hd), dtype),
        # cross K/V, computed at prefill from the encoder output
        "xk": jnp.zeros((Ld, batch, seq_len, K, hd), dtype),
        "xv": jnp.zeros((Ld, batch, seq_len, K, hd), dtype),
    }


def decode_step(params, cfg: ModelConfig, cache, tokens, pos,
                constrain=lambda t, k: t):
    """One decoder step against self KV + precomputed cross KV."""
    x = constrain(L.embed_apply(params["embed"], tokens), "act")
    B_ = tokens.shape[0]
    Ss = cache["xk"].shape[2]

    def scan_fn(x, inp):
        lp, kc, vc, xk, xv = inp
        h = L.rms_norm(lp["ln1"], x, cfg.norm_eps)
        a, kc, vc = L.attn_decode(lp["attn"], cfg, h, pos, kc, vc)
        x = constrain(x + a, "act")
        # cross-attention reads the static encoder KV (no rope, no update)
        h = L.rms_norm(lp["lnx"], x, cfg.norm_eps)
        H, K, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
        q = L.matmul(h, lp["cross"]["wq"]).reshape(B_, 1, H, hd)
        bias = jnp.zeros((B_, 1, Ss), jnp.float32)
        o = L.attention_scores(q, xk, xv, bias)
        x = constrain(
            x + L.matmul(o.reshape(B_, 1, H * hd), lp["cross"]["wo"]), "act")
        h = L.rms_norm(lp["ln2"], x, cfg.norm_eps)
        x = constrain(x + L.mlp_apply(lp["mlp"], h), "act")
        return x, (kc, vc)

    x, (kc, vc) = jax.lax.scan(
        scan_fn, x,
        (params["dec"], cache["k"], cache["v"], cache["xk"], cache["xv"]))
    x = L.rms_norm(params["ln_f"], x, cfg.norm_eps)
    return L.logits_apply(params["embed"], x), dict(cache, k=kc, v=vc)
