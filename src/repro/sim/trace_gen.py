"""Synthetic memory-access trace generators (stand-ins for the paper's
Sniper traces of GraphBIG / XSBench / GUPS / DLRM / GenomicsBench).

Each generator emits a trace dict {vpn:int32, is2m:bool, line:int32} plus
metadata.  Traces are *statistically calibrated* to the paper's reported
translation behaviour: L2-TLB MPKI ≫ 5 with THP 4K/2M mixes, ~92% of L2
data blocks exhibiting zero reuse (Fig. 11), and PTW latencies centered
≈137 cycles (Fig. 4).  vpns are page ids inside a contiguous VA region
(heap-like), so upper PT levels exhibit realistic PWC locality while leaf
PTE lines carry 8-page spatial clusters — the structure Victima exploits.

Generation is no longer a serial pre-pass: ``generate`` is thread-safe
and seed-stable (its own ``np.random.Generator`` per call, no module
state), so ``generate_many`` and ``runner.run_ladder``'s producer pool
overlap trace generation with the compiled simulate dispatches and the
results stay bit-identical to one-at-a-time calls — the property the
seed-keyed sim cache relies on.
"""
from __future__ import annotations

import dataclasses
import os
import zlib
from concurrent.futures import ThreadPoolExecutor

import numpy as np

GB = 1 << 30
PAGE4 = 4096
PAGE2 = 2 << 20
LINES_PER_PAGE4 = 64  # 4KB / 64B


@dataclasses.dataclass(frozen=True)
class WorkloadSpec:
    name: str
    footprint_gb: float      # dataset size
    thp_frac: float          # fraction of ACCESSES hitting 2M-backed VA
    zipf_a: float | None     # zipf exponent for hot-page skew (None=uniform)
    seq_frac: float          # fraction of accesses in sequential runs
    seq_run: int             # lines per sequential run
    ipa: float = 3.0         # instructions per memory access
    reref_frac: float = 0.0  # P(revisit a recent page); fresh line within —
    #                          page-level temporal locality WITHOUT creating
    #                          line-level cache reuse (Fig. 11 stays ~92%)
    reref_window: int = 2000
    # mid-range working set (vertex arrays / lookup tables revisited every
    # iteration): larger than the 1.5K-entry L2 TLB, within the reach of
    # large TLB structures — the regime Fig. 20/21 discriminates on.
    hot_frac: float = 0.55   # P(base access lands in the hot region)
    hot_pages: int = 32_000  # hot-region size in 4K pages (~128 MB —
    #   cycles ~2.6× per 150K-access trace, so its translations are
    #   re-usable but far outside the 1.5K-entry L2 TLB)


# 11 workloads from Table 4 (GraphBIG ×7, XSBench, GUPS, DLRM, GenomicsBench).
# thp_frac reflects real THP behaviour on these suites: dense heap arrays
# partially 2M-backed (fragmentation limits THP coverage on these
# irregular suites — consistent with the paper's mostly-4K 220MB reach); pointer-
# heavy / fragmented regions stay 4K (paper extracts page sizes from a real
# THP system, §8).
WORKLOADS: dict[str, WorkloadSpec] = {
    "bc":   WorkloadSpec("bc", 6.0, 0.30, 1.05, 0.25, 24, ipa=3.5,
                         reref_frac=0.86),
    "bfs":  WorkloadSpec("bfs", 6.0, 0.28, 1.10, 0.30, 24, ipa=3.5,
                         reref_frac=0.82),
    "cc":   WorkloadSpec("cc", 6.0, 0.30, 1.08, 0.25, 24, ipa=3.5,
                         reref_frac=0.86),
    "gc":   WorkloadSpec("gc", 6.0, 0.28, 1.05, 0.20, 16, ipa=3.0,
                         reref_frac=0.82),
    "pr":   WorkloadSpec("pr", 6.0, 0.35, 1.02, 0.30, 32, ipa=3.0,
                         reref_frac=0.88),
    "tc":   WorkloadSpec("tc", 6.0, 0.25, 1.12, 0.20, 16, ipa=3.0,
                         reref_frac=0.78),
    "sp":   WorkloadSpec("sp", 6.0, 0.30, 1.08, 0.25, 24, ipa=3.5,
                         reref_frac=0.84),
    "xs":   WorkloadSpec("xs", 9.0, 0.35, None, 0.15, 48, ipa=4.0,
                         reref_frac=0.85),
    "rnd":  WorkloadSpec("rnd", 10.0, 0.30, None, 0.00, 1, ipa=6.0,
                         reref_frac=0.0, hot_frac=0.45),
    "dlrm": WorkloadSpec("dlrm", 10.3, 0.35, 1.05, 0.20, 32, ipa=4.0,
                         reref_frac=0.82),
    "gen":  WorkloadSpec("gen", 16.0, 0.15, None, 0.10, 16, ipa=3.0,
                         reref_frac=0.70, hot_frac=0.35, hot_pages=64_000),
}

LINE_REUSE_FRAC = 0.18  # fraction of rerefs that reuse the exact line —
#                         produces the paper's ~8% non-zero L2 data reuse

MAX_PAGES4 = 1 << 23  # counter-table bound (≈32GB footprint)


def _zipf_pages(rng: np.random.Generator, n: int, n_pages: int,
                a: float) -> np.ndarray:
    """Zipf-ish page popularity via inverse-CDF over a permuted id space."""
    # sample ranks with P(r) ∝ r^-a using Zipf rejection, clipped
    r = rng.zipf(a + 1e-9 if a > 1.0 else 1.0001, size=n)
    r = np.minimum(r - 1, n_pages - 1)
    # permute so hot pages are scattered across the VA region
    salt = np.uint64(0x9E3779B97F4A7C15)
    pr = (r.astype(np.uint64) * salt) % np.uint64(n_pages)
    return pr.astype(np.int64)


def generate(name: str, n: int = 400_000, seed: int = 0) -> dict:
    """Generate a trace for workload `name`.

    Returns {"trace": {vpn,is2m,line}, "spec": WorkloadSpec,
             "n_pages": int (TOTAL 4K-page-equivalents, including the
             2M-backed region), "n_pages_2m_region": int} with numpy
    arrays (callers jnp-ify).

    Thread-safe and seed-stable: every call builds its OWN
    ``np.random.Generator`` from (seed, name) and touches no module
    state, so concurrent generation (``generate_many``, the
    ``runner.run_ladder`` producer pool) is bit-identical to sequential
    calls regardless of scheduling — the property the seed-keyed sim
    cache relies on.
    """
    spec = WORKLOADS[name]
    # stable per-workload salt: str.hash() is process-salted, which made
    # traces (and therefore disk-cached Stats) irreproducible across runs
    rng = np.random.default_rng(seed + zlib.crc32(name.encode()) % 65536)

    n_pages = min(int(spec.footprint_gb * GB / PAGE4), MAX_PAGES4)
    # VA layout: first `n4` pages are 4K-backed, rest belong to 2M regions.
    n4 = int(n_pages * (1.0 - spec.thp_frac))
    n4 = max(512, n4 - (n4 % 512))          # align to 2M boundaries
    n2_pages4 = n_pages - n4                 # 4K-page-equivalents in THP area

    # --- base random page stream (hot/cold skew)
    if spec.zipf_a is None:
        pages = rng.integers(0, n_pages, size=n, dtype=np.int64)
    else:
        pages = _zipf_pages(rng, n, n_pages, spec.zipf_a)

    # --- mid-range hot region: a CONTIGUOUS VA range (vertex array /
    # lookup-table style), so 8-page PTE clusters cover it densely —
    # 160K pages need only 20K TLB blocks (fits the 32K-block L2)
    if spec.hot_frac > 0:
        H = min(spec.hot_pages, n_pages)
        hot_ids = rng.integers(0, H, size=n)
        in_hot = rng.random(n) < spec.hot_frac
        pages = np.where(in_hot, hot_ids, pages)

    # --- splice sequential runs (streaming phases)
    if spec.seq_frac > 0:
        n_seq = int(n * spec.seq_frac)
        n_runs = max(1, n_seq // max(spec.seq_run // LINES_PER_PAGE4, 1))
        run_pages = max(spec.seq_run // LINES_PER_PAGE4, 1)
        starts = rng.integers(0, max(n_pages - run_pages, 1), size=n_runs)
        seq = (starts[:, None] + np.arange(run_pages)[None, :]).reshape(-1)
        seq = seq[: n_seq]
        pos = rng.choice(n, size=len(seq), replace=False)
        pages[pos] = seq

    line_in_page = rng.integers(0, LINES_PER_PAGE4, size=n, dtype=np.int64)

    # --- page-level temporal re-reference (see WorkloadSpec.reref_frac);
    # a minority of rerefs reuse the exact line too (L2 data reuse tail)
    if spec.reref_frac > 0:
        u = rng.random(n)
        d = rng.integers(1, spec.reref_window, size=n)
        src = np.maximum(np.arange(n) - d, 0)
        take = u < spec.reref_frac
        # resolve reref chains (a reref may point at another reref) by
        # fixed-point iteration — 4 rounds covers >99% of chains
        for _ in range(4):
            pages = np.where(take, pages[src], pages)
        same_line = take & (rng.random(n) < LINE_REUSE_FRAC)
        for _ in range(4):
            line_in_page = np.where(same_line, line_in_page[src],
                                    line_in_page)

    pages = pages % n_pages
    is2m = pages >= n4
    vpn = pages.astype(np.int32)
    line = (pages * LINES_PER_PAGE4 + line_in_page).astype(np.int32)

    return {
        "trace": {
            "vpn": vpn,
            "is2m": is2m.astype(np.bool_),
            "line": line,
        },
        "spec": spec,
        # total page count (4K-page-equivalents) — NOT just the 4K-backed
        # region; the old "n_pages4" name wrongly suggested the latter
        "n_pages": n_pages,
        "n_pages_2m_region": n2_pages4 // 512,
    }


# per-core seed skew for multiprogrammed mixes: two cores co-scheduled on
# the SAME workload must still run independent access streams (prime,
# far outside the sweep's seed range so skewed streams never collide
# with another seed's un-skewed stream)
MIX_SEED_SKEW = 7919


def parse_mix(spec: str) -> list[str]:
    """Validate a ``+``-separated co-schedule spec (``"bc+rnd+xs"``).

    Returns the component workload names in spec order.  Raises
    ``ValueError`` naming every unknown component — the same validate-
    before-compile contract as the sweep's system/tag name checks, so a
    typo dies in argument parsing instead of after minutes of tracing.
    """
    names = [s.strip() for s in str(spec).split("+")]
    if not names or any(not s for s in names):
        raise ValueError(f"malformed mix spec {spec!r} (want 'a+b+c')")
    unknown = sorted(set(s for s in names if s not in WORKLOADS))
    if unknown:
        raise ValueError(
            f"unknown workload(s) in mix {spec!r}: {', '.join(unknown)}; "
            f"known: {', '.join(WORKLOADS)}")
    return names


def generate_mix(spec: str, n: int = 400_000, seed: int = 0,
                 n_cores: int = 1, workers: int | None = None) -> dict:
    """Multiprogrammed co-schedule of ``spec`` over ``n_cores`` lanes.

    The arbiter is round-robin-with-skew: component workloads are dealt
    to core lanes round-robin (``core c`` runs ``names[c % len(names)]``,
    so a 2-workload mix on 4 cores co-schedules each twice), every lane
    is an independent ``generate`` stream whose seed is skewed per core
    (``seed + MIX_SEED_SKEW * c`` — two lanes of the SAME workload do
    not alias), and the scan interleaves the lanes in lock-step on the
    trace's core axis.  Leaf-for-leaf, lane ``c`` is bit-identical to
    the serial ``generate(names[c % k], n, seed + MIX_SEED_SKEW * c)``
    (pinned by tests/test_multicore.py), so mixes inherit ``generate``'s
    thread-safety and seed-stability — the sim cache keys on
    (mix, n, seed) exactly like a plain workload.

    Returns the ``generate`` dict shape with per-core leaves [n, C],
    plus a ``core`` lane-id leaf and a per-lane ``ipa`` leaf; ``spec``
    is the tuple of per-core WorkloadSpecs.
    """
    names = parse_mix(spec)
    if n_cores < 1:
        raise ValueError(f"n_cores must be >= 1, got {n_cores}")
    assign = [names[c % len(names)] for c in range(n_cores)]
    workers = workers or min(n_cores, os.cpu_count() or 1, 8)
    with ThreadPoolExecutor(max_workers=workers) as pool:
        outs = list(pool.map(
            lambda c: generate(assign[c], n=n,
                               seed=seed + MIX_SEED_SKEW * c),
            range(n_cores)))
    trace = {k: np.stack([o["trace"][k] for o in outs], axis=1)
             for k in outs[0]["trace"]}
    trace["core"] = np.broadcast_to(
        np.arange(n_cores, dtype=np.int32), (n, n_cores))
    trace["ipa"] = np.broadcast_to(
        np.asarray([o["spec"].ipa for o in outs], dtype=np.float32),
        (n, n_cores))
    return {
        "trace": trace,
        "spec": tuple(o["spec"] for o in outs),
        "n_pages": max(o["n_pages"] for o in outs),
        "n_pages_2m_region": max(o["n_pages_2m_region"] for o in outs),
    }


def generate_many(names, n: int = 400_000, seed: int = 0,
                  workers: int | None = None) -> list[dict]:
    """Generate traces for ``names`` on a thread pool, in input order.

    numpy releases the GIL inside its kernels, so generation genuinely
    overlaps on multi-core hosts; results are bit-identical to serial
    ``generate`` calls (see its thread-safety note — pinned by
    tests/test_parallel.py for seeds 0/1/7 across every workload).
    """
    names = list(names)
    if not names:
        return []
    workers = workers or min(len(names), os.cpu_count() or 1, 8)
    with ThreadPoolExecutor(max_workers=workers) as pool:
        return list(pool.map(lambda w: generate(w, n=n, seed=seed), names))


def all_workloads() -> list[str]:
    return list(WORKLOADS.keys())
