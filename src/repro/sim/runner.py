"""Simulation driver: evaluated-system presets (Table 3) + cached runs."""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import pickle

import jax

# persistent XLA compile cache: sim step graphs take minutes to compile
# on this 1-core container; compile once across processes.
jax.config.update("jax_compilation_cache_dir",
                  os.environ.get("JAX_CACHE", "/root/repo/.jax_cache"))
jax.config.update("jax_persistent_cache_min_compile_time_secs", 5)

import jax.numpy as jnp
import numpy as np

from repro.core.mmu import SimConfig, simulate, simulate_batch
from repro.sim import trace_gen

CACHE_DIR = os.environ.get("REPRO_SIM_CACHE", "/root/repo/.sim_cache")


def system_config(system: str) -> SimConfig:
    """Named presets for every evaluated system (paper Table 3)."""
    base = SimConfig()
    presets = {
        # --- native
        "radix": base,
        "victima": dataclasses.replace(base, victima=True),
        "victima_agnostic": dataclasses.replace(
            base, victima=True, tlb_aware=False),
        "victima_noptwcp": dataclasses.replace(
            base, victima=True, use_ptwcp=False),
        "pom": dataclasses.replace(base, pom=True),
        # optimistic large L2 TLBs (12-cycle regardless of size)
        "l2tlb_3k": dataclasses.replace(base, l2tlb_sets=256),
        "l2tlb_8k": dataclasses.replace(base, l2tlb_sets=512, l2tlb_ways=16),
        "l2tlb_16k": dataclasses.replace(base, l2tlb_sets=1024, l2tlb_ways=16),
        "l2tlb_32k": dataclasses.replace(base, l2tlb_sets=2048, l2tlb_ways=16),
        "l2tlb_64k": dataclasses.replace(base, l2tlb_sets=4096, l2tlb_ways=16),
        "l2tlb_128k": dataclasses.replace(base, l2tlb_sets=8192, l2tlb_ways=16),
        # realistic latencies from CACTI 7.0 (paper §3.1: 1.4× per 2×)
        "l2tlb_8k_real": dataclasses.replace(
            base, l2tlb_sets=512, l2tlb_ways=16, l2tlb_lat=17),
        "l2tlb_16k_real": dataclasses.replace(
            base, l2tlb_sets=1024, l2tlb_ways=16, l2tlb_lat=23),
        "l2tlb_32k_real": dataclasses.replace(
            base, l2tlb_sets=2048, l2tlb_ways=16, l2tlb_lat=30),
        "l2tlb_64k_real": dataclasses.replace(
            base, l2tlb_sets=4096, l2tlb_ways=16, l2tlb_lat=39),
        # hardware L3 TLB (64K entries) at various latencies
        "l3tlb_64k_15": dataclasses.replace(base, l3tlb_sets=4096, l3tlb_lat=15),
        "l3tlb_64k_24": dataclasses.replace(base, l3tlb_sets=4096, l3tlb_lat=24),
        "l3tlb_64k_39": dataclasses.replace(base, l3tlb_sets=4096, l3tlb_lat=39),
        # --- L2 cache size sensitivity (Fig. 25): 1/4/8 MB
        "victima_l2_1m": dataclasses.replace(base, victima=True,
                                             l2_sets=1024),
        "victima_l2_4m": dataclasses.replace(base, victima=True,
                                             l2_sets=4096),
        "victima_l2_8m": dataclasses.replace(base, victima=True,
                                             l2_sets=8192),
        "radix_l2_1m": dataclasses.replace(base, l2_sets=1024),
        "radix_l2_4m": dataclasses.replace(base, l2_sets=4096),
        "radix_l2_8m": dataclasses.replace(base, l2_sets=8192),
        # --- Table 2 feature collection
        "radix_collect": dataclasses.replace(base, collect=True),
        # --- virtualized
        "np": dataclasses.replace(base, virt=True),
        "victima_virt": dataclasses.replace(base, virt=True, victima=True),
        "pom_virt": dataclasses.replace(base, virt=True, pom=True),
        "isp": dataclasses.replace(base, virt=True, ideal_shadow=True),
    }
    return presets[system]


def _key(system: str, workload: str, n: int, seed: int,
         overrides: dict | None) -> str:
    blob = json.dumps([system, workload, n, seed, overrides or {}],
                      sort_keys=True)
    return hashlib.sha1(blob.encode()).hexdigest()[:16]


def _path(system, workload, n, seed, overrides):
    os.makedirs(CACHE_DIR, exist_ok=True)
    key = _key(system, workload, n, seed, overrides)
    return os.path.join(CACHE_DIR, key + ".pkl")


def run_batch(system: str, workloads=None, n: int = 150_000, seed: int = 0,
              overrides: dict | None = None, cache: bool = True):
    """Simulate one system over ALL workloads in a single vmapped scan.

    Fills the per-(system, workload) disk cache; returns dict
    workload → (stats, extras, spec).
    """
    workloads = workloads or trace_gen.all_workloads()
    missing = [w for w in workloads
               if not (cache and os.path.exists(
                   _path(system, w, n, seed, overrides)))]
    out = {}
    if missing:
        gens = [trace_gen.generate(w, n=n, seed=seed) for w in missing]
        cfg = system_config(system)
        if overrides:
            cfg = dataclasses.replace(cfg, **overrides)
        stacked = {
            k: jnp.asarray(np.stack([g["trace"][k] for g in gens], axis=1))
            for k in gens[0]["trace"]
        }
        stacked["ipa"] = jnp.asarray(
            np.broadcast_to(
                np.asarray([g["spec"].ipa for g in gens], np.float32),
                (n, len(gens))))
        per, extras = simulate_batch(cfg, stacked)
        for w, g, st, ex in zip(missing, gens, per, extras):
            st = type(st)(*[np.asarray(x) for x in st])
            result = (st, ex, g["spec"])
            with open(_path(system, w, n, seed, overrides), "wb") as f:
                pickle.dump(result, f)
    for w in workloads:
        with open(_path(system, w, n, seed, overrides), "rb") as f:
            out[w] = pickle.load(f)
    return out


def run(system: str, workload: str, n: int = 150_000, seed: int = 0,
        overrides: dict | None = None, cache: bool = True):
    """Simulate one (system, workload). Returns (stats, extras, spec).

    Results are cached on disk — the benchmark harness reruns cheaply.
    """
    path = _path(system, workload, n, seed, overrides)
    if cache and os.path.exists(path):
        with open(path, "rb") as f:
            return pickle.load(f)

    gen = trace_gen.generate(workload, n=n, seed=seed)
    cfg = system_config(system)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    cfg = dataclasses.replace(cfg, ipa=gen["spec"].ipa)
    trace = {k: jnp.asarray(v) for k, v in gen["trace"].items()}
    trace["ipa"] = jnp.full((len(gen["trace"]["vpn"]),), gen["spec"].ipa,
                            jnp.float32)
    stats, extras = simulate(cfg, trace)
    stats = type(stats)(*[np.asarray(x) for x in stats])
    result = (stats, extras, gen["spec"])
    if cache:
        with open(path, "wb") as f:
            pickle.dump(result, f)
    return result
