"""Simulation driver: cached runs over the system registry.

Systems are declared in ``repro.sim.systems``; this module turns
(system, workload) pairs into disk-cached Stats.  Cache writes are
crash-safe (temp file + atomic rename) and unreadable entries are
treated as missing, so an interrupted sweep can never poison later
runs.  ``run_ladder`` fills a whole shape-compatible system ladder
through one compiled shard_map kernel, as a producer/consumer pipeline:
trace generation runs on a background thread pool and overlaps with the
device-meshed simulate calls, which dispatch in fixed-width workload
chunks so every chunk reuses the SAME compiled shape.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import math
import os
import pickle
import tempfile
from concurrent.futures import ThreadPoolExecutor

import jax

# persistent XLA compile cache: sim step graphs take minutes to compile
# on this 1-core container; compile once across processes.
jax.config.update("jax_compilation_cache_dir",
                  os.environ.get("JAX_CACHE", "/root/repo/.jax_cache"))
jax.config.update("jax_persistent_cache_min_compile_time_secs", 5)

import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

import repro.obs as obs  # noqa: E402
from repro.analysis import recompile  # noqa: E402
from repro.core import mmu  # noqa: E402
from repro.core.mmu import (  # noqa: E402
    make_systems_runner, simulate, simulate_batch)
from repro.kernels import mmu_step  # noqa: E402
from repro.obs import jaxprof  # noqa: E402
from repro.sim import parallel, systems, trace_gen  # noqa: E402

CACHE_DIR = os.environ.get("REPRO_SIM_CACHE", "/root/repo/.sim_cache")

# ladder dispatch width: workloads per compiled simulate call.  The last
# chunk pads by repeating its final workload, so EVERY run_ladder call —
# whatever its missing-workload count — compiles exactly one [S, CHUNK]
# shape (the old whole-missing-set dispatch recompiled for each distinct
# count), and trace generation overlaps with the previous chunk's sim.
# REPRO_SIM_CHUNK=auto (the default) derives the width per fill from the
# workload count via ``auto_chunk``; an integer pins it.
_chunk_env = os.environ.get("REPRO_SIM_CHUNK", "auto").strip().lower()
CHUNK: int | None = None if _chunk_env in ("", "auto") else int(_chunk_env)

# auto_chunk ceiling: padded-lane waste shrinks with wider chunks but
# compile time and per-dispatch memory grow; measured schema-2 fills put
# the knee near 8 lanes on this container
CHUNK_MAX = int(os.environ.get("REPRO_SIM_CHUNK_MAX", 8))

# background trace-generation threads for the run_ladder producer pool
GEN_WORKERS = int(os.environ.get("REPRO_GEN_WORKERS", 4))

# perf-trajectory records: one entry per batched ladder fill this process
# ran.  Since schema 5 these are NOT hand-assembled: every fill runs
# under a ``ladder_fill`` obs span tree (trace_gen / chunk_wait /
# dispatch children, xla_compile events) and the record is DERIVED from
# the tracer's events by ``obs.report.fill_record`` — the same function
# ``python -m repro.obs report`` applies to the JSONL file, so the
# artifact is reconstructible bit-exactly offline (and ``--check``
# proves it).  Field meanings: trace_gen_wall_s = consumer-side wait
# (generation NOT hidden behind simulation), trace_gen_true_wall_s =
# producer-side thread time, compile_plus_sim_wall_s = the compiled
# shard_map dispatches; see obs.report.FIELD_SOURCES for the full
# field->source table.  benchmarks/paper.write_sweep_artifact dumps
# them to BENCH_sweep.json so CI can track sweep-throughput regressions.
LADDER_PERF: list[dict] = []


def auto_chunk(n_workloads: int, cap: int | None = None) -> int:
    """Pick the ladder dispatch width from the workload count.

    The fill's wall time is ``n_dispatch * (overhead + chunk *
    lane_cost)``: with one reusable compiled runner per fill, the
    per-dispatch overhead is small against the per-lane sim cost, so
    the measured-cost ordering is (1) fewest dispatches, (2) least
    padded-lane waste — e.g. a 3-workload fill picks chunk=3 (one
    dispatch, zero padding) where the old fixed default of 4 simulated
    a fourth, discarded lane (+33% sim work).  Ties prefer the NARROWER
    chunk (faster compile).  ``cap`` bounds the width (default
    ``CHUNK_MAX``); the chunk count derives from the FULL workload list,
    not the missing count, so partially-cached reruns keep hitting the
    same compiled [S, chunk] shape.
    """
    if n_workloads <= 0:
        raise ValueError(f"no workloads to chunk (n={n_workloads})")
    cap = cap or CHUNK_MAX
    return min(range(1, min(cap, n_workloads) + 1),
               key=lambda c: (math.ceil(n_workloads / c),
                              c * math.ceil(n_workloads / c) - n_workloads,
                              c))


def system_config(system: str):
    """Named preset for an evaluated system (delegates to the registry)."""
    return systems.config(system)


def _sim_config(system: str, overrides: dict | None):
    """The ONE place a run's SimConfig is materialized.

    ``run``, ``run_batch`` and ``run_ladder`` all store under the same
    cache key, so the Stats they produce must not depend on which code
    path filled the entry — any config tweak must happen here.  (The
    per-access ``ipa`` rides in the trace itself, never in the config.)
    """
    cfg = systems.config(system)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    return cfg


def _canon(v):
    """Canonicalize an override value for hashing.

    ``json.dumps`` crashes on dataclasses/NamedTuples (``Lat``) and
    numpy/jnp scalars, and reprs could alias distinct overrides; this
    maps them to stable, tagged JSON-able structures instead.
    """
    if dataclasses.is_dataclass(v) and not isinstance(v, type):
        fields = sorted(dataclasses.fields(v), key=lambda f: f.name)
        return {"__dataclass__": type(v).__name__,
                **{f.name: _canon(getattr(v, f.name)) for f in fields}}
    if isinstance(v, tuple) and hasattr(v, "_fields"):  # NamedTuple (Lat)
        return {"__namedtuple__": type(v).__name__,
                **{k: _canon(x) for k, x in sorted(v._asdict().items())}}
    if isinstance(v, (np.generic, np.ndarray)) or isinstance(v, jax.Array):
        a = np.asarray(v)
        return a.item() if a.ndim == 0 else [_canon(x) for x in a.tolist()]
    if isinstance(v, dict):
        return {str(k): _canon(x) for k, x in v.items()}
    if isinstance(v, (list, tuple)):
        return [_canon(x) for x in v]
    if v is None or isinstance(v, (bool, int, float, str)):
        return v
    # a repr() fallback would be process-unstable (object addresses) and
    # silently defeat the cache — unknown types must fail loudly
    raise TypeError(
        f"cannot canonicalize override value of type {type(v).__name__}: "
        f"{v!r}")


def _key(system: str, workload: str, n: int, seed: int,
         overrides: dict | None) -> str:
    blob = json.dumps([system, workload, n, seed, _canon(overrides or {})],
                      sort_keys=True)
    return hashlib.sha1(blob.encode()).hexdigest()[:16]


def _path(system, workload, n, seed, overrides):
    os.makedirs(CACHE_DIR, exist_ok=True)
    key = _key(system, workload, n, seed, overrides)
    return os.path.join(CACHE_DIR, key + ".pkl")


def _store(path: str, result) -> None:
    """Atomic pickle write: an interrupted run leaves no truncated entry."""
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path), suffix=".tmp")
    try:
        with os.fdopen(fd, "wb") as f:
            pickle.dump(result, f)
        os.replace(tmp, path)
        obs.count(obs.names.CTR_SIM_CACHE_STORE, emit=True)
    except BaseException:
        if os.path.exists(tmp):
            os.unlink(tmp)
        raise


def _load(path: str):
    """Read a cache entry; unreadable entries count as missing.

    Corrupt bytes from an interrupted legacy write (or stale pickles
    referencing renamed modules) raise a grab-bag of exception types —
    anything short of a successful load means "recompute".
    """
    try:
        with open(path, "rb") as f:
            return pickle.load(f)
    except Exception:
        return None


def _cached(path: str, cache: bool):
    if not cache:
        return None
    got = _load(path) if os.path.exists(path) else None
    # unreadable entries already count as missing in _load; mirror that
    # split into the obs registry (hit = a usable entry came back)
    obs.count(obs.names.CTR_SIM_CACHE_HIT if got is not None
              else obs.names.CTR_SIM_CACHE_MISS, emit=True)
    return got


def _np_stats(st):
    return type(st)(*[np.asarray(x) for x in st])


def _stack_traces(gens, n: int) -> dict:
    keys = set(gens[0]["trace"])
    for g in gens:
        if set(g["trace"]) != keys:
            # a mismatched generator used to surface as a bare KeyError
            # deep in the stacking comprehension — name the workload
            raise ValueError(
                f"workload {g['spec'].name!r} emits trace keys "
                f"{sorted(g['trace'])} but {gens[0]['spec'].name!r} "
                f"emits {sorted(keys)}; every generator in a batched "
                f"run must produce the same trace fields")
    stacked = {
        k: jnp.asarray(np.stack([g["trace"][k] for g in gens], axis=1))
        for k in gens[0]["trace"]
    }
    # multiprogrammed-mix traces already carry per-lane "ipa" (and
    # "core") leaves — stacked above like any other key; only synthesize
    # the per-workload broadcast for plain single-core generators
    if "ipa" not in stacked:
        stacked["ipa"] = jnp.asarray(
            np.broadcast_to(
                np.asarray([g["spec"].ipa for g in gens], np.float32),
                (n, len(gens))))
    return stacked


def run_batch(system: str, workloads=None, n: int = 150_000, seed: int = 0,
              overrides: dict | None = None, cache: bool = True,
              backend: str | None = None, block: int | None = None):
    """Simulate one system over ALL workloads in a single vmapped scan.

    Fills the per-(system, workload) disk cache; returns dict
    workload -> (stats, extras, spec).  ``backend``/``block`` select the
    access-loop implementation (bit-identical; never part of cache keys).
    """
    workloads = workloads or trace_gen.all_workloads()
    if _sim_config(system, overrides).n_cores > 1:
        # multicore: core lanes occupy the batch axis per workload/mix,
        # so batch per-workload via run (same cache keys either way)
        return {w: run(system, w, n=n, seed=seed, overrides=overrides,
                       cache=cache, backend=backend, block=block)
                for w in workloads}
    out = {}
    missing = []
    for w in workloads:
        got = _cached(_path(system, w, n, seed, overrides), cache)
        if got is None:
            missing.append(w)
        else:
            out[w] = got
    if missing:
        gens = trace_gen.generate_many(missing, n=n, seed=seed)
        cfg = _sim_config(system, overrides)
        # overrides may change the composition (e.g. victima=True on
        # radix): let make_step re-derive the stages from the final cfg
        stage_names = None if overrides else systems.get(system).stages
        per, extras = simulate_batch(cfg, _stack_traces(gens, n),
                                     stage_names=stage_names,
                                     backend=backend, block=block)
        for w, g, st, ex in zip(missing, gens, per, extras):
            result = (_np_stats(st), ex, g["spec"])
            _store(_path(system, w, n, seed, overrides), result)
            out[w] = result
    return {w: out[w] for w in workloads}


def run_ladder(ladder: str, workloads=None, n: int = 150_000,
               seed: int = 0, cache: bool = True, members=None,
               chunk: int | None = None, mesh=None,
               backend: str | None = None, block: int | None = None,
               time_shards: int = 1):
    """Fill the cache for a whole system ladder through ONE compiled
    kernel, pipelined over a ("sys", "wl") device mesh.

    All ladder members (e.g. the 28-system native family incl. the
    Fig. 25 L2-cache sizes, or the virt family) are vmapped over their
    Dyn sizing scalars; the system axis is padded to the mesh (see
    ``parallel.plan_mesh``), so any member count works on any device
    count.  The run is a producer/consumer pipeline: trace generation
    for missing workloads runs on a background thread pool while the
    compiled simulate call chews on the previous chunk — ``chunk``
    workloads per dispatch (default ``CHUNK``), the last chunk padded by
    repeating its final workload so every dispatch shares one compiled
    [S, chunk] shape.  Chunking and meshing cannot change results:
    every (system, workload) lane computes independently, so cache
    entries stay byte-compatible with per-system ``run_batch`` results
    (pinned by the multidev tests).  `members` restricts the run to a
    subset of the ladder; `mesh=(sys, wl)` forces the mesh factorization
    (debug).  ``backend``/``block``/``time_shards`` select the access
    loop (scan or pallas; see ``mmu.BACKENDS``) — all bit-identical, so
    cache entries never record the backend.  ``time_shards > 1``
    requires a 1x1 mesh (devices go to the time axis).  Returns dict
    system -> dict workload -> result.
    """
    members = tuple(members or systems.LADDERS[ladder])
    workloads = workloads or trace_gen.all_workloads()
    out = {s: {} for s in members}
    missing = []
    for w in workloads:
        got = {s: _cached(_path(s, w, n, seed, None), cache)
               for s in members}
        # reuse every cached (member, workload) cell as-is; a workload
        # only re-simulates when at least one member's cell is missing
        # (the batched call covers all lanes anyway), and even then the
        # cached cells are neither recomputed nor rewritten below.
        for s, r in got.items():
            if r is not None:
                out[s][w] = r
        if any(r is None for r in got.values()):
            missing.append(w)
    if not missing:
        return out
    cfg = systems.ladder_base_config(ladder, members)
    dyns = systems.ladder_dyn(members)
    # mix-aware dispatch: a multicore family generates [T, W, C]
    # multiprogrammed traces (every "workload" is a mix spec — a plain
    # name is the 1-component mix) and stores per-core result tuples
    n_cores = cfg.n_cores
    # never shrink the dispatch width to the missing count: a
    # partially-cached rerun must reuse the SAME compiled [S, chunk]
    # shape (short groups pad below), and a forced mesh planned for
    # `chunk` must stay valid however few workloads are left — which is
    # also why auto_chunk sees the FULL workload list, never `missing`
    auto = chunk is None and CHUNK is None
    chunk = chunk or CHUNK or auto_chunk(len(workloads))
    if time_shards > 1 and mesh is None:
        mesh = (1, 1)  # devices go to the ("t",) axis instead
    plan = parallel.plan_mesh(len(members), chunk,
                              force=tuple(mesh) if mesh else None,
                              n_cores=n_cores)
    backend = mmu.resolve_backend(backend)
    # ONE runner for all chunks: every chunk dispatches the same
    # [S, chunk] shape, so the shard_map kernel traces/compiles once
    run_fn = make_systems_runner(cfg, plan, backend=backend, block=block,
                                 time_shards=time_shards)
    n_chunks = 0
    # one-compile accounting (schema >= 4): the dispatch graph must
    # compile once for the whole fill.  The time-shard path re-jits its
    # per-round function every dispatch (a known per-chunk retrace), so
    # its count is per-chunk — recorded honestly, not masked.
    dispatch_fn = (recompile.DISPATCH_NAME if time_shards <= 1
                   else "round_fn")
    tr = obs.tracer()
    fill = obs.span(
        obs.names.SPAN_LADDER_FILL,
        ladder=ladder, n_systems=len(members), n_members=len(members),
        n_workloads=len(missing), sim_n=n,
        devices=jax.local_device_count(),
        mesh=([plan.sys_dim, plan.wl_dim, plan.core_dim]
              if plan.core_dim > 1 else [plan.sys_dim, plan.wl_dim]),
        cores=n_cores,
        chunk=chunk, chunk_auto=auto, backend=backend,
        block=(mmu_step.pick_block(n, block)
               if backend == "pallas" else None),
        dispatch_fn=dispatch_fn)

    def _gen(w):
        # producer-side TRUE generation time: runs on a pool worker
        # thread, so the fill parent must be attached explicitly
        with obs.span(obs.names.SPAN_TRACE_GEN, parent=fill, wl=w):
            if n_cores > 1:
                return trace_gen.generate_mix(w, n=n, seed=seed,
                                              n_cores=n_cores)
            return trace_gen.generate(w, n=n, seed=seed)

    with fill:
        with jaxprof.maybe_profile(), recompile.count_compiles(
                on_compile=lambda name: obs.event(
                    obs.names.EV_COMPILE, parent=fill, fn=name)), \
                ThreadPoolExecutor(
                    max_workers=min(len(missing), GEN_WORKERS)) as pool:
            futs = {w: pool.submit(_gen, w) for w in missing}
            for lo in range(0, len(missing), chunk):
                group = missing[lo:lo + chunk]
                # consumer-side wait: generation NOT hidden behind sim
                with obs.span(obs.names.SPAN_CHUNK_WAIT,
                              workloads=list(group)):
                    gens = [futs[w].result() for w in group]
                # pad the workload axis to the fixed chunk width: padded
                # lanes re-simulate the last workload and are never stored
                padded = gens + [gens[-1]] * (chunk - len(gens))
                # the base composition may contain dyn-gated stages some
                # members lack (radix lanes riding a victima ladder):
                # the runner derives the stages from cfg
                with obs.span(obs.names.SPAN_DISPATCH,
                              chunk_index=n_chunks, workloads=list(group),
                              cores=n_cores):
                    per, extras = run_fn(dyns, _stack_traces(padded, n))
                n_chunks += 1
                for si, s in enumerate(members):
                    for wi, (w, g) in enumerate(zip(group, gens)):
                        if w in out[s]:
                            continue  # pre-existing cell: keep cached bytes
                        if n_cores > 1:
                            # multicore cell: per-core tuples (one Stats/
                            # extras per lane), spec = per-core spec tuple
                            result = (
                                tuple(_np_stats(p) for p in per[si][wi]),
                                tuple(extras[si][wi]), g["spec"])
                        else:
                            result = (_np_stats(per[si][wi]),
                                      extras[si][wi], g["spec"])
                        _store(_path(s, w, n, seed, None), result)
                        out[s][w] = result
        tinfo = getattr(run_fn, "last_time_shard_info", None)
        fill.set(n_chunks=n_chunks,
                 t_shards=tinfo["t_shards"] if tinfo else 1,
                 t_rounds=tinfo["rounds"] if tinfo else None)
        jaxprof.device_memory_event(obs.event)  # no-op on CPU backends
    # the record is DERIVED from the just-closed span tree by the same
    # function the offline CLI uses — see the LADDER_PERF comment above
    LADDER_PERF.append(obs.report.fill_record(tr.events, fill.id, tr.path))
    return out


def run(system: str, workload: str, n: int = 150_000, seed: int = 0,
        overrides: dict | None = None, cache: bool = True,
        backend: str | None = None, block: int | None = None,
        time_shards: int = 1):
    """Simulate one (system, workload). Returns (stats, extras, spec).

    Results are cached on disk — the benchmark harness reruns cheaply.
    ``backend``/``block``/``time_shards`` pick the access-loop
    implementation (bit-identical; never part of cache keys).
    """
    path = _path(system, workload, n, seed, overrides)
    got = _cached(path, cache)
    if got is not None:
        return got

    cfg = _sim_config(system, overrides)
    stage_names = None if overrides else systems.get(system).stages
    if cfg.n_cores > 1:
        # multicore: `workload` is a mix spec (a plain name = the
        # 1-component mix); the per-core lanes ride the vmapped batch
        # axis, and the result is a per-core tuple like run_ladder's
        gen = trace_gen.generate_mix(workload, n=n, seed=seed,
                                     n_cores=cfg.n_cores)
        trace = {k: jnp.asarray(v) for k, v in gen["trace"].items()}
        per, extras = simulate_batch(cfg, trace, stage_names=stage_names,
                                     backend=backend, block=block)
        result = (tuple(_np_stats(s) for s in per), tuple(extras),
                  gen["spec"])
        if cache:
            _store(path, result)
        return result

    gen = trace_gen.generate(workload, n=n, seed=seed)
    trace = {k: jnp.asarray(v) for k, v in gen["trace"].items()}
    trace["ipa"] = jnp.full((len(gen["trace"]["vpn"]),), gen["spec"].ipa,
                            jnp.float32)
    stats, extras = simulate(cfg, trace, stage_names=stage_names,
                             backend=backend, block=block,
                             time_shards=time_shards)
    result = (_np_stats(stats), extras, gen["spec"])
    if cache:
        _store(path, result)
    return result
