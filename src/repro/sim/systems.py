"""Declarative registry of evaluated systems (paper Table 3 + ablations).

Each ``System`` names its translation-pipeline stage composition (see
repro.core.stages) plus the SimConfig overrides that size it.  Ladders
are discovered automatically (``discover_ladders``): systems whose
configs differ only in ``DYN_FIELDS`` (L2-TLB geometry/latency, L3-TLB
latency, L2-*cache* geometry, RestSeg associativity, and the
dyn-gateable rev/victima/restseg/l3_tlb/pom stage flags) batch into ONE
compiled, vmapped call per ladder (mmu.simulate_systems) — the whole
radix/victima/utopia/POM/L3-TLB native family shares one compile.

Adding a new translation scheme = writing a stage module + registering
a System here; see docs/architecture.md.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.stages import DYN_FIELDS, Dyn, default_stages, dyn_of
from repro.core.mmu import SimConfig

# stage compositions (tuples shared across entries for readability)
_RADIX = ("l1_tlb", "l2_tlb", "ptw")
_VICTIMA = ("l1_tlb", "l2_tlb", "victima", "ptw")
_L3 = ("l1_tlb", "l2_tlb", "l3_tlb", "ptw")
_POM = ("l1_tlb", "l2_tlb", "pom", "ptw")
_UTOPIA = ("l1_tlb", "l2_tlb", "restseg", "ptw")
_UTOPIA_VICTIMA = ("l1_tlb", "l2_tlb", "victima", "restseg", "ptw")
_REV = ("l1_tlb", "l2_tlb", "rev", "ptw")
_REV_VICTIMA = ("l1_tlb", "l2_tlb", "rev", "victima", "ptw")
_REV_NP = ("l1_tlb", "l2_tlb", "rev", "ptw2d")
_NP = ("l1_tlb", "l2_tlb", "ptw2d")
_VICTIMA_NP = ("l1_tlb", "l2_tlb", "victima", "ptw2d")
_POM_NP = ("l1_tlb", "l2_tlb", "pom", "ptw2d")
_UTOPIA_NP = ("l1_tlb", "l2_tlb", "restseg", "ptw2d")


@dataclasses.dataclass(frozen=True)
class System:
    """One evaluated system: stage composition + config overrides."""

    name: str
    stages: tuple[str, ...]
    overrides: dict
    desc: str = ""
    tags: tuple[str, ...] = ()

    def config(self, base: SimConfig | None = None) -> SimConfig:
        return dataclasses.replace(base or SimConfig(), **self.overrides)


REGISTRY: dict[str, System] = {}


def register(name: str, stages: tuple[str, ...], desc: str = "",
             tags: tuple[str, ...] = (), **overrides) -> System:
    if name in REGISTRY:
        raise ValueError(f"duplicate system {name!r}")
    sys_ = System(name=name, stages=stages, overrides=overrides,
                  desc=desc, tags=tags)
    got = default_stages(sys_.config())
    if stages != got:
        raise ValueError(
            f"system {name!r} declares stages {stages} but its config "
            f"implies {got}")
    REGISTRY[name] = sys_
    return sys_


def get(name: str) -> System:
    try:
        return REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown system {name!r}; registered: "
                       f"{', '.join(sorted(REGISTRY))}") from None


def config(name: str) -> SimConfig:
    return get(name).config()


def names(tag: str | None = None) -> list[str]:
    return [n for n, s in REGISTRY.items() if tag is None or tag in s.tags]


# --------------------------------------------------------------- native
register("radix", _RADIX, "baseline 2-level TLB + 4-level radix PTW",
         tags=("native", "l2tlb_ladder"))
register("victima", _VICTIMA, "TLB blocks in L2$ + PTW-CP + TLB-aware SRRIP",
         tags=("native", "headline"), victima=True)
register("victima_agnostic", _VICTIMA, "Victima with TLB-agnostic SRRIP "
         "(Fig. 26 ablation)", tags=("native", "ablation"),
         victima=True, tlb_aware=False)
register("victima_noptwcp", _VICTIMA, "Victima inserting every candidate "
         "(no PTW-CP ablation)", tags=("native", "ablation"),
         victima=True, use_ptwcp=False)
register("pom", _POM, "64K-entry software-managed in-memory L3 TLB",
         tags=("native",), pom=True)

# optimistic large L2 TLBs (12-cycle regardless of size; Figs. 5-6)
for _n, _sets, _ways in [("3k", 256, 12), ("8k", 512, 16),
                         ("16k", 1024, 16), ("32k", 2048, 16),
                         ("64k", 4096, 16), ("128k", 8192, 16)]:
    register(f"l2tlb_{_n}", _RADIX, f"optimistic {_n}-entry L2 TLB",
             tags=("native", "l2tlb_ladder"),
             l2tlb_sets=_sets, l2tlb_ways=_ways)

# realistic latencies from CACTI 7.0 (paper §3.1: 1.4x per 2x; Fig. 7)
for _n, _sets, _lat in [("8k", 512, 17), ("16k", 1024, 23),
                        ("32k", 2048, 30), ("64k", 4096, 39)]:
    register(f"l2tlb_{_n}_real", _RADIX,
             f"{_n}-entry L2 TLB at CACTI latency {_lat}c",
             tags=("native", "l2tlb_ladder"),
             l2tlb_sets=_sets, l2tlb_ways=16, l2tlb_lat=_lat)

# hardware L3 TLB (64K entries) at various latencies (Fig. 8)
for _lat in (15, 24, 39):
    register(f"l3tlb_64k_{_lat}", _L3, f"64K-entry hardware L3 TLB @{_lat}c",
             tags=("native", "l3tlb_ladder"),
             l3tlb_sets=4096, l3tlb_lat=_lat)

# L2 cache size sensitivity (Fig. 25): 1/4/8 MB
for _n, _sets in [("1m", 1024), ("4m", 4096), ("8m", 8192)]:
    register(f"victima_l2_{_n}", _VICTIMA, f"Victima with {_n}B L2 cache",
             tags=("native", "sensitivity"), victima=True, l2_sets=_sets)
    register(f"radix_l2_{_n}", _RADIX, f"radix with {_n}B L2 cache",
             tags=("native", "sensitivity"), l2_sets=_sets)

# Table 2 feature collection
register("radix_collect", _RADIX, "radix + per-page feature collection",
         tags=("native", "collect"), collect=True)

# ------------------------------------------------------------- utopia
# Hybrid RestSeg/FlexSeg mapping (PAPERS.md): set-associative RestSegs
# resolve translations with one near-free tag probe; the FlexSeg falls
# back to the radix walkers.  The PTW-CP-guided migration engine shares
# Victima's predictor, so the combined system costs no extra hardware.
register("utopia", _UTOPIA, "hybrid RestSeg/FlexSeg mapping + "
         "PTW-CP-guided page migration", tags=("native", "headline",
         "utopia"), utopia=True)
register("utopia_victima", _UTOPIA_VICTIMA, "Utopia RestSegs + Victima "
         "TLB blocks in L2$ (shared PTW-CP)", tags=("native", "utopia"),
         utopia=True, victima=True)
# RestSeg-associativity sensitivity ladder (joins the radix/victima
# family automatically via the restseg_ways Dyn field)
for _w in (8, 32):
    register(f"utopia_rs{_w}", _UTOPIA, f"Utopia with {_w}-way RestSegs",
             tags=("native", "sensitivity", "utopia"),
             utopia=True, restseg_ways=_w)

# ------------------------------------------------------------ revelator
# Hash-based speculative translation (PAPERS.md, arXiv 2508.02007): a
# signature hit on L2-TLB miss resolves the translation at near-zero
# latency while the walk verifies off the critical path; only a
# mispredict pays the overlapped walk cost.  Enrollment reuses the
# PTW-CP predictor, completing the scheme-comparison matrix (radix /
# Victima / Utopia / Revelator) on shared hardware assumptions.
register("revelator", _REV, "hash-based speculative translation + "
         "verify-later walks", tags=("native", "headline", "revelator"),
         revelator=True)
register("revelator_victima", _REV_VICTIMA, "Revelator speculation over "
         "Victima TLB blocks in L2$ (shared PTW-CP)",
         tags=("native", "revelator"), revelator=True, victima=True)

# --------------------------------------------------------------- virtualized
register("np", _NP, "nested paging: 2-D walk + nested TLB",
         tags=("virt",), virt=True)
register("victima_virt", _VICTIMA_NP, "Victima under nested paging "
         "(gVA + nested TLB blocks in L2$)", tags=("virt", "headline"),
         virt=True, victima=True)
register("pom_virt", _POM_NP, "POM-TLB under nested paging",
         tags=("virt",), virt=True, pom=True)
register("utopia_virt", _UTOPIA_NP, "Utopia under nested paging (guest "
         "RestSegs short-circuit the 2-D walk)", tags=("virt", "utopia"),
         virt=True, utopia=True)
register("revelator_virt", _REV_NP, "Revelator under nested paging (a "
         "correct prediction hides the whole 2-D walk)",
         tags=("virt", "revelator"), virt=True, revelator=True)
register("isp", _RADIX, "ideal shadow paging: 1-D walk, free updates",
         tags=("virt",), virt=True, ideal_shadow=True)

# --------------------------------------------------------------- multicore
# Per-core private TLB hierarchies (the core axis rides the trace's
# [T, W, C] lanes) over a shared tier: the L3 cache and POM-TLB are
# statically partitioned (total capacity / n_cores per core family) and
# a rotating-port queueing delay models contention on the path past the
# private L2 TLB (SimConfig.shared_port_cyc).  ``victima_dramc_*`` adds
# the die-stacked DRAM cache below the L3.  The 1-core members are the
# degenerate case — per-lane bit-identical to the single-core systems
# above; ``shared_tier_stats`` both surfaces the shared-tier counters in
# extras and keeps these families' ladder keys distinct from the native
# family, whose compiled graph must stay byte-for-byte untouched.
for _c in (1, 2, 4):
    _mc = dict(n_cores=_c, shared_tier_stats=True,
               l3_sets=2048 // _c, pom_sets=4096 // _c)
    register(f"radix_{_c}c", _RADIX,
             f"{_c}-core radix: private TLBs, shared contended L3",
             tags=("multicore", f"{_c}c"), **_mc)
    register(f"victima_{_c}c", _VICTIMA,
             f"{_c}-core Victima over the shared contended tier",
             tags=("multicore", f"{_c}c", "headline"), victima=True, **_mc)
    register(f"pom_{_c}c", _POM,
             f"{_c}-core POM-TLB (shared in-memory L3 TLB, partitioned)",
             tags=("multicore", f"{_c}c"), pom=True, **_mc)
    register(f"victima_dramc_{_c}c", _VICTIMA,
             f"{_c}-core Victima + die-stacked DRAM cache below the L3",
             tags=("multicore", f"{_c}c", "dramc"), victima=True,
             dram_cache_sets=4096 // _c, **_mc)


def mix_cores(members) -> int:
    """Core-lane count shared by a ladder's members (mix-aware ladder
    discovery: a >1 answer tells the runner/sweep to generate [T, W, C]
    multiprogrammed-mix traces for this family)."""
    cores = {config(n).n_cores for n in members}
    if len(cores) != 1:
        raise ValueError(
            f"ladder members disagree on n_cores: {sorted(cores)} "
            f"(n_cores is static — core-count variants are separate "
            f"families)")
    return cores.pop()


# --------------------------------------------------------------- ladders
#
# Ladders are DISCOVERED, not declared: any group of registered systems
# whose configs agree after pinning DYN_FIELDS — and whose compositions
# agree after dropping dyn-*gateable* stages — batches into one compiled
# vmapped simulate_systems call.  Registering a new size/latency variant
# automatically joins it to its family's ladder.

# stages that a batched ladder can switch off per-lane via a Dyn gate
# (the stage still runs compiled, but its state writes are masked to a
# bit-exact no-op): stage name -> (SimConfig field, Dyn gate).  The
# config field is how dyn_of derives the gate (l3_tlb gates on
# l3tlb_sets > 0; the rest on their bool flag).
DYN_GATED_STAGES: dict[str, tuple[str, str]] = {
    "rev": ("revelator", "rev_en"),
    "victima": ("victima", "victima_en"),
    "restseg": ("utopia", "utopia_en"),
    "l3_tlb": ("l3tlb_sets", "l3tlb_en"),
    "pom": ("pom", "pom_en"),
}


def _ladder_key(sys_: System):
    """Systems with equal keys are shape-compatible ladder mates."""
    cfg = sys_.config()
    pinned = dataclasses.replace(
        cfg, **{f: getattr(SimConfig(), f) for f in DYN_FIELDS})
    stages = tuple(s for s in sys_.stages if s not in DYN_GATED_STAGES)
    return stages, pinned


def discover_ladders(registry: dict[str, System] | None = None
                     ) -> dict[str, tuple[str, ...]]:
    """Group registry systems into shape-compatible ladders.

    Returns {ladder_name: member names} for every group of >= 2 systems;
    the ladder is named after its first-registered member.  Singletons
    run through the per-system batched path instead.
    """
    registry = REGISTRY if registry is None else registry
    groups: dict = {}
    for name, sys_ in registry.items():
        groups.setdefault(_ladder_key(sys_), []).append(name)
    return {g[0]: tuple(g) for g in groups.values() if len(g) >= 2}


def ladder_base_config(ladder: str | None = None, members=None) -> SimConfig:
    """Static config for a ladder: structures at the ladder maximum.

    Validates shape-compatibility — members may differ only in
    DYN_FIELDS (everything else must match the first member).  Every dyn
    field takes its ladder maximum (bool stage flags are ORed so the
    base composition contains every stage any member needs; lanes
    without it mask it off via their Dyn gate).
    """
    members = members or LADDERS[ladder]
    cfgs = [config(n) for n in members]
    pinned = {f: getattr(cfgs[0], f) for f in DYN_FIELDS}
    norm = {dataclasses.replace(c, **pinned) for c in cfgs}
    if len(norm) != 1:
        raise ValueError(
            f"ladder {ladder or members[0]!r} members differ beyond "
            f"{DYN_FIELDS}")
    # the L3 TLB has no dyn set mask (only an on/off gate + latency), so
    # every member that HAS one must match the base allocation exactly
    l3max = max(c.l3tlb_sets for c in cfgs)
    for n, c in zip(members, cfgs):
        if c.l3tlb_sets not in (0, l3max):
            raise ValueError(
                f"ladder member {n!r}: l3tlb_sets={c.l3tlb_sets} differs "
                f"from the ladder maximum {l3max} (the L3 TLB is "
                f"gateable but not geometry-virtualized)")
    # same contract for the die-stacked DRAM cache: an on/off gate
    # (Dyn.dramc_en) but no set-mask virtualization
    dcmax = max(c.dram_cache_sets for c in cfgs)
    for n, c in zip(members, cfgs):
        if c.dram_cache_sets not in (0, dcmax):
            raise ValueError(
                f"ladder member {n!r}: dram_cache_sets="
                f"{c.dram_cache_sets} differs from the ladder maximum "
                f"{dcmax} (the DRAM cache is gateable but not "
                f"geometry-virtualized)")
    return dyn_base_config(cfgs)


def dyn_base_config(cfgs) -> SimConfig:
    """The maximal static allocation covering every config's live view:
    each DYN_FIELDS entry takes its maximum (bool stage flags are ORed,
    so the base composition contains every gated stage any cfg needs)."""
    maxima = {}
    for f in DYN_FIELDS:
        vals = [getattr(c, f) for c in cfgs]
        maxima[f] = any(vals) if isinstance(getattr(SimConfig(), f), bool) \
            else max(vals)
    return dataclasses.replace(cfgs[0], **maxima)


def ladder_dyn(members) -> Dyn:
    """Stacked per-system Dyn scalars ([S]-leaves) for ladder members.

    Derived by stacking ``dyn_of`` per member so the field-to-config
    mapping lives in exactly one place (stages.base.dyn_of).
    """
    dyns = [dyn_of(config(n)) for n in members]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *dyns)


LADDERS: dict[str, tuple[str, ...]] = discover_ladders()
