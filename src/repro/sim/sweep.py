"""Populate the simulation result cache for every (system x workload) the
benchmark suite needs.  Run as ``python -m repro.sim.sweep`` (results
land in .sim_cache and benchmarks read them instantly).

Shape-compatible system ladders are discovered from the registry
(``systems.LADDERS``) — e.g. the 28-system native family (radix /
victima / utopia / revelator, L2-TLB sizes incl. CACTI variants, the
Fig. 25 L2-cache sizes, POM and the L3-TLB latency trio) — and filled
by ONE
compiled vmapped call each via ``run_ladder``; the remaining systems
run through the per-system batched path.

CLI: positional system names and/or ``--tags native,ablation`` to
select registry subsets by tag without listing names, e.g.

    python -m repro.sim.sweep --tags utopia
    python -m repro.sim.sweep radix --tags sensitivity
"""
from __future__ import annotations

import os
import sys
import time

from repro.sim import systems
from repro.sim.runner import run_batch, run_ladder

N = int(os.environ.get("REPRO_SIM_N", 150_000))

# priority order: paper-headline systems first so partial sweeps are useful
SYSTEMS = [
    "radix",
    "victima",
    "utopia",
    "revelator",
    "utopia_victima",
    "revelator_victima",
    "pom",
    "l2tlb_64k",
    "l2tlb_128k",
    "np",
    "victima_virt",
    "isp",
    "pom_virt",
    "l2tlb_3k",
    "l2tlb_8k",
    "l2tlb_16k",
    "l2tlb_32k",
    "l3tlb_64k_15",
    "l3tlb_64k_24",
    "l3tlb_64k_39",
    "l2tlb_8k_real",
    "l2tlb_16k_real",
    "l2tlb_32k_real",
    "l2tlb_64k_real",
    "victima_agnostic",
    "victima_noptwcp",
    "radix_collect",
    "victima_l2_1m",
    "victima_l2_4m",
    "victima_l2_8m",
    "radix_l2_1m",
    "radix_l2_4m",
    "radix_l2_8m",
    "utopia_rs8",
    "utopia_rs32",
    "utopia_virt",
    "revelator_virt",
]


def parse_args(args):
    """Split a CLI arg list into (system names, tags).

    ``--tags native,ablation`` (or ``--tags=...``) selects every system
    carrying any of the given registry tags; positional names add
    individual systems on top.
    """
    def _tag_list(val, flag):
        # "--tags --foo" used to swallow the next OPTION as a tag list;
        # flag-like values are always a CLI mistake, so error out
        if val is None or val.startswith("-"):
            raise SystemExit(
                f"{flag} needs a comma-separated value"
                + (f", got {val!r}" if val is not None else ""))
        return [t for t in val.split(",") if t]

    names, tags = [], []
    it = iter(args or [])
    for a in it:
        if a == "--tags":
            tags += _tag_list(next(it, None), "--tags")
        elif a.startswith("--tags="):
            tags += _tag_list(a.split("=", 1)[1], "--tags=")
        elif a.startswith("-"):
            raise SystemExit(f"unknown option {a!r} (only --tags)")
        else:
            names.append(a)
    return names, tags


def main(selected=None):
    selected, tags = parse_args(selected)
    # validate CLI names/tags BEFORE any simulation: a typo used to burn
    # the full ladder compile and then die with a KeyError mid-sweep
    unknown = sorted(set(selected) - set(systems.REGISTRY))
    if unknown:
        raise SystemExit(
            f"unknown system(s): {', '.join(unknown)}; registered: "
            f"{', '.join(sorted(systems.REGISTRY))}")
    all_tags = {t for s in systems.REGISTRY.values() for t in s.tags}
    bad_tags = sorted(set(tags) - all_tags)
    if bad_tags:
        raise SystemExit(
            f"unknown tag(s): {', '.join(bad_tags)}; known: "
            f"{', '.join(sorted(all_tags))}")
    for t in tags:
        selected += [n for n in systems.names(t) if n not in selected]
    selected = selected or SYSTEMS
    t00 = time.time()
    done: set[str] = set()
    # batched ladders first: one compilation covers many systems.  A
    # CLI-selected subset only simulates the selected members.
    for ladder, members in systems.LADDERS.items():
        todo = [s for s in members if s in selected]
        if not todo:
            continue
        t0 = time.time()
        run_ladder(ladder, n=N, members=todo)
        done.update(todo)
        print(f"[sweep] ladder:{ladder:>11s} x all  {time.time()-t0:7.1f}s "
              f"({len(todo)} systems, 1 compile; "
              f"total {time.time()-t00:7.0f}s)", flush=True)
    for sysname in selected:
        if sysname in done:
            continue
        t0 = time.time()
        run_batch(sysname, n=N)
        print(f"[sweep] {sysname:>18s} x all  {time.time()-t0:7.1f}s "
              f"(total {time.time()-t00:7.0f}s)", flush=True)


if __name__ == "__main__":
    main(sys.argv[1:] or None)
