"""Populate the simulation result cache for every (system x workload) the
benchmark suite needs.  Run as ``python -m repro.sim.sweep`` (results
land in .sim_cache and benchmarks read them instantly).

Shape-compatible system ladders are discovered from the registry
(``systems.LADDERS``) — e.g. the 28-system native family (radix /
victima / utopia / revelator, L2-TLB sizes incl. CACTI variants, the
Fig. 25 L2-cache sizes, POM and the L3-TLB latency trio) — and filled
by ONE
compiled vmapped call each via ``run_ladder``; the remaining systems
run through the per-system batched path.

CLI: positional system names and/or ``--tags native,ablation`` to
select registry subsets by tag without listing names, e.g.

    python -m repro.sim.sweep --tags utopia
    python -m repro.sim.sweep radix --tags sensitivity

Mesh debugging: ``--devices N`` forces N virtual host devices (sets
``--xla_force_host_platform_device_count`` before the first device
query) and ``--mesh SxW`` (or ``SxWxC`` for multicore families) pins
the ladder ("sys", "wl"[, "core"]) mesh factorization, e.g.

    python -m repro.sim.sweep --devices 4 --mesh 2x2 --tags headline

Multicore: ``--cores C`` selects the registered C-core systems (per-core
private TLBs over the shared contended L3/POM tier; see
docs/architecture.md) and ``--mix bc+rnd+xs`` names a multiprogrammed
co-schedule for them — repeatable, validated against the workload
registry BEFORE anything compiles, and only applied to multicore
families (single-core ladders keep their default workload list):

    python -m repro.sim.sweep --cores 4 --mix bc+rnd+xs --mix dlrm+gen

Backend selection: ``--backend {scan,pallas}`` picks the access-loop
implementation (bit-identical results; pallas runs in interpreter mode
off-TPU) and ``--time-shards N`` splits each trace's time axis into N
speculative blocks resolved to the exact serial carry — it needs a 1x1
("sys", "wl") mesh, so it conflicts with ``--mesh`` unless that is 1x1.

Observability: ``--obs-trace PATH`` points the process-global obs
tracer at PATH, so every ladder fill's span tree lands in that JSONL
file (``python -m repro.obs report PATH`` rolls it up; equivalent to
``REPRO_OBS_TRACE=PATH``, which also covers non-sweep entry points like
``benchmarks/run.py``).
"""
from __future__ import annotations

import os
import sys
import time

import repro.obs as obs
from repro.core import mmu
from repro.sim import systems, trace_gen
from repro.sim.runner import run_batch, run_ladder

N = int(os.environ.get("REPRO_SIM_N", 150_000))

# priority order: paper-headline systems first so partial sweeps are useful
SYSTEMS = [
    "radix",
    "victima",
    "utopia",
    "revelator",
    "utopia_victima",
    "revelator_victima",
    "pom",
    "l2tlb_64k",
    "l2tlb_128k",
    "np",
    "victima_virt",
    "isp",
    "pom_virt",
    "l2tlb_3k",
    "l2tlb_8k",
    "l2tlb_16k",
    "l2tlb_32k",
    "l3tlb_64k_15",
    "l3tlb_64k_24",
    "l3tlb_64k_39",
    "l2tlb_8k_real",
    "l2tlb_16k_real",
    "l2tlb_32k_real",
    "l2tlb_64k_real",
    "victima_agnostic",
    "victima_noptwcp",
    "radix_collect",
    "victima_l2_1m",
    "victima_l2_4m",
    "victima_l2_8m",
    "radix_l2_1m",
    "radix_l2_4m",
    "radix_l2_8m",
    "utopia_rs8",
    "utopia_rs32",
    "utopia_virt",
    "revelator_virt",
]


def parse_args(args):
    """Split a CLI arg list into (system names, tags, opts).

    ``--tags native,ablation`` (or ``--tags=...``) selects every system
    carrying any of the given registry tags; positional names add
    individual systems on top.  ``opts`` carries the mesh debug flags —
    ``--mesh SxW`` (forced ("sys", "wl") factorization) and
    ``--devices N`` (forced virtual host device count) — plus the
    access-loop knobs ``--backend {scan,pallas}`` and
    ``--time-shards N``.  All values are validated HERE, before any
    compilation: an unknown backend must die instantly, not after the
    ladder compile (mirroring the --tags fix).
    """
    def _value(val, flag, what="a comma-separated value"):
        # "--tags --foo" used to swallow the next OPTION as a value;
        # flag-like values are always a CLI mistake, so error out
        if val is None or val.startswith("-"):
            raise SystemExit(
                f"{flag} needs {what}"
                + (f", got {val!r}" if val is not None else ""))
        return val

    def _mesh(val, flag):
        parts = _value(val, flag, "a SYSxWL[xCORE] value").split("x")
        if len(parts) not in (2, 3) or not all(p.isdigit() for p in parts):
            raise SystemExit(f"{flag} wants SYSxWL or SYSxWLxCORE "
                             f"(e.g. 2x2 or 1x2x2), got {val!r}")
        return tuple(int(p) for p in parts)

    def _devices(val, flag):
        if not _value(val, flag, "a device count").isdigit() or int(val) < 1:
            raise SystemExit(f"{flag} wants a positive integer, got {val!r}")
        return int(val)

    def _backend(val, flag):
        val = _value(val, flag, "a backend name")
        try:
            return mmu.resolve_backend(val)
        except ValueError as e:
            raise SystemExit(str(e)) from None

    def _tshards(val, flag):
        if not _value(val, flag, "a shard count").isdigit() or int(val) < 1:
            raise SystemExit(f"{flag} wants a positive integer, got {val!r}")
        return int(val)

    def _obs_trace(val, flag):
        return _value(val, flag, "a file path")

    def _cores(val, flag):
        if not _value(val, flag, "a core count").isdigit() or int(val) < 1:
            raise SystemExit(f"{flag} wants a positive integer, got {val!r}")
        return int(val)

    def _mix(val, flag):
        # validate the co-schedule spec's workload names HERE, before
        # anything compiles — same contract as system names and --tags
        val = _value(val, flag, "a workload mix like bc+rnd+xs")
        try:
            trace_gen.parse_mix(val)
        except ValueError as e:
            raise SystemExit(str(e)) from None
        return val

    names, tags = [], []
    opts = {"mesh": None, "devices": None, "backend": None,
            "time_shards": 1, "obs_trace": None, "cores": None, "mix": []}
    it = iter(args or [])
    for a in it:
        if a == "--tags":
            tags += [t for t in _value(next(it, None), "--tags").split(",")
                     if t]
        elif a.startswith("--tags="):
            tags += [t for t in _value(a.split("=", 1)[1], "--tags=")
                     .split(",") if t]
        elif a == "--mesh":
            opts["mesh"] = _mesh(next(it, None), "--mesh")
        elif a.startswith("--mesh="):
            opts["mesh"] = _mesh(a.split("=", 1)[1], "--mesh=")
        elif a == "--devices":
            opts["devices"] = _devices(next(it, None), "--devices")
        elif a.startswith("--devices="):
            opts["devices"] = _devices(a.split("=", 1)[1], "--devices=")
        elif a == "--backend":
            opts["backend"] = _backend(next(it, None), "--backend")
        elif a.startswith("--backend="):
            opts["backend"] = _backend(a.split("=", 1)[1], "--backend=")
        elif a == "--time-shards":
            opts["time_shards"] = _tshards(next(it, None), "--time-shards")
        elif a.startswith("--time-shards="):
            opts["time_shards"] = _tshards(a.split("=", 1)[1],
                                           "--time-shards=")
        elif a == "--obs-trace":
            opts["obs_trace"] = _obs_trace(next(it, None), "--obs-trace")
        elif a.startswith("--obs-trace="):
            opts["obs_trace"] = _obs_trace(a.split("=", 1)[1],
                                           "--obs-trace=")
        elif a == "--cores":
            opts["cores"] = _cores(next(it, None), "--cores")
        elif a.startswith("--cores="):
            opts["cores"] = _cores(a.split("=", 1)[1], "--cores=")
        elif a == "--mix":
            opts["mix"].append(_mix(next(it, None), "--mix"))
        elif a.startswith("--mix="):
            opts["mix"].append(_mix(a.split("=", 1)[1], "--mix="))
        elif a.startswith("-"):
            raise SystemExit(
                f"unknown option {a!r} (only --tags/--mesh/--devices/"
                f"--backend/--time-shards/--obs-trace/--cores/--mix)")
        else:
            names.append(a)
    if opts["time_shards"] > 1 and opts["mesh"] is not None \
            and any(d != 1 for d in opts["mesh"]):
        raise SystemExit(
            f"--time-shards needs a 1x1 ('sys', 'wl') mesh (devices go "
            f"to the 't' axis), got --mesh "
            f"{'x'.join(str(d) for d in opts['mesh'])}")
    return names, tags, opts


def main(selected=None):
    selected, tags, opts = parse_args(selected)
    if opts["obs_trace"]:
        obs.configure(opts["obs_trace"])
    if opts["devices"]:
        # mesh debugging: force N virtual CPU devices.  This only works
        # BEFORE the first jax device query initializes the backend —
        # importing repro.sim.* touches no devices, so setting it here
        # (not in runner) is early enough.
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count={opts['devices']}"
        ).strip()
    # validate CLI names/tags BEFORE any simulation: a typo used to burn
    # the full ladder compile and then die with a KeyError mid-sweep
    unknown = sorted(set(selected) - set(systems.REGISTRY))
    if unknown:
        raise SystemExit(
            f"unknown system(s): {', '.join(unknown)}; registered: "
            f"{', '.join(sorted(systems.REGISTRY))}")
    all_tags = {t for s in systems.REGISTRY.values() for t in s.tags}
    bad_tags = sorted(set(tags) - all_tags)
    if bad_tags:
        raise SystemExit(
            f"unknown tag(s): {', '.join(bad_tags)}; known: "
            f"{', '.join(sorted(all_tags))}")
    for t in tags:
        selected += [n for n in systems.names(t) if n not in selected]
    if opts["cores"] is not None:
        mc = [n for n, s in systems.REGISTRY.items()
              if "multicore" in s.tags
              and s.config().n_cores == opts["cores"]]
        if not mc:
            known = sorted({s.config().n_cores
                            for s in systems.REGISTRY.values()
                            if "multicore" in s.tags})
            raise SystemExit(
                f"no registered multicore systems with n_cores="
                f"{opts['cores']}; registered core counts: "
                f"{', '.join(map(str, known))}")
        selected += [n for n in mc if n not in selected]
    selected = selected or SYSTEMS
    t00 = time.time()
    done: set[str] = set()
    # batched ladders first: one compilation covers many systems.  A
    # CLI-selected subset only simulates the selected members.
    for ladder, members in systems.LADDERS.items():
        todo = [s for s in members if s in selected]
        if not todo:
            continue
        t0 = time.time()
        # --mix co-schedules apply to multicore families only; every
        # other family keeps its default workload list
        wl = (opts["mix"] or None) if systems.mix_cores(todo) > 1 else None
        run_ladder(ladder, n=N, members=todo, workloads=wl,
                   mesh=opts["mesh"], backend=opts["backend"],
                   time_shards=opts["time_shards"])
        done.update(todo)
        print(f"[sweep] ladder:{ladder:>11s} x all  {time.time()-t0:7.1f}s "
              f"({len(todo)} systems, 1 compile; "
              f"total {time.time()-t00:7.0f}s)", flush=True)
    for sysname in selected:
        if sysname in done:
            continue
        t0 = time.time()
        wl = ((opts["mix"] or None)
              if systems.config(sysname).n_cores > 1 else None)
        run_batch(sysname, n=N, workloads=wl, backend=opts["backend"])
        print(f"[sweep] {sysname:>18s} x all  {time.time()-t0:7.1f}s "
              f"(total {time.time()-t00:7.0f}s)", flush=True)


if __name__ == "__main__":
    main(sys.argv[1:] or None)
