"""Populate the simulation result cache for every (system × workload) the
benchmark suite needs.  Run as ``python -m repro.sim.sweep`` (hours on one
core; results land in .sim_cache and benchmarks read them instantly).
"""
from __future__ import annotations

import sys
import time

from repro.sim import trace_gen
from repro.sim.runner import run_batch

N = int(__import__("os").environ.get("REPRO_SIM_N", 150_000))

# priority order: paper-headline systems first so partial sweeps are useful
SYSTEMS = [
    "radix",
    "victima",
    "pom",
    "l2tlb_64k",
    "l2tlb_128k",
    "np",
    "victima_virt",
    "isp",
    "pom_virt",
    "l2tlb_3k",
    "l2tlb_8k",
    "l2tlb_16k",
    "l2tlb_32k",
    "l3tlb_64k_15",
    "l3tlb_64k_24",
    "l3tlb_64k_39",
    "l2tlb_8k_real",
    "l2tlb_16k_real",
    "l2tlb_32k_real",
    "l2tlb_64k_real",
    "victima_agnostic",
    "victima_noptwcp",
    "radix_collect",
    "victima_l2_1m",
    "victima_l2_4m",
    "victima_l2_8m",
    "radix_l2_1m",
    "radix_l2_4m",
    "radix_l2_8m",
]


def main(systems=None):
    systems = systems or SYSTEMS
    t00 = time.time()
    for sysname in systems:
        t0 = time.time()
        run_batch(sysname, n=N)
        print(f"[sweep] {sysname:>18s} × all  {time.time()-t0:7.1f}s "
              f"(total {time.time()-t00:7.0f}s)", flush=True)


if __name__ == "__main__":
    main(sys.argv[1:] or None)
