"""Device-mesh planning + shard_map dispatch for batched ladder sweeps.

A batched ladder run is an [S]-system x [W]-workload grid of mutually
independent scans (``mmu.simulate_systems``).  This module spreads that
grid over a 2-D ``("sys", "wl")`` device mesh:

- ``plan_mesh`` factorizes the visible devices into mesh dims.  The
  workload dim must divide W exactly (traces are big; we never pad
  them here — ``runner.run_ladder`` fixes W via chunking instead); the
  system dim may be anything, because ``shard_systems`` PADS the system
  axis up to a mesh multiple — "S divides the device count evenly" is
  NOT a precondition.
- ``shard_systems`` places the inputs (``NamedSharding``: Dyn leaves
  ``P("sys")``, trace leaves ``P(None, "wl")``), wraps the caller's
  per-block function in ``shard_map`` and slices the padding back off.
  On a 1x1 mesh the same code path degenerates to an identity
  partitioning of a plain jitted call, so single-device hosts (CI)
  exercise the exact production code.

Every (s, w) lane's computation is independent and elementwise per
lane, so the mesh factorization cannot change results: a sharded run is
bit-identical to the unsharded one (pinned by tests/test_parallel.py
and the multidev CI job).

This module deliberately imports nothing from ``repro.core`` or its
``repro.sim`` siblings — it is a pure pytree/mesh utility, so the core
layer (``mmu.simulate_systems``) may import it without a cycle.
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

try:  # jax >= 0.6 promotes shard_map out of experimental
    from jax import shard_map  # type: ignore[attr-defined]
except ImportError:
    from jax.experimental.shard_map import shard_map

AXIS_SYS = "sys"
AXIS_WL = "wl"

__all__ = ["AXIS_SYS", "AXIS_WL", "MeshPlan", "plan_mesh", "build_mesh",
           "shard_wrap", "shard_systems"]


@dataclasses.dataclass(frozen=True)
class MeshPlan:
    """A (sys x wl) device-mesh factorization for an S x W sweep grid."""

    sys_dim: int       # mesh extent along the system axis
    wl_dim: int        # mesh extent along the workload axis (divides W)
    n_systems: int     # unpadded S
    n_workloads: int   # W
    pad_systems: int   # S padded up to a sys_dim multiple

    @property
    def n_devices(self) -> int:
        return self.sys_dim * self.wl_dim

    def describe(self) -> str:
        return f"{self.sys_dim}x{self.wl_dim}"


def plan_mesh(n_systems: int, n_workloads: int, n_devices: int | None = None,
              force: tuple[int, int] | None = None) -> MeshPlan:
    """Factorize the device count into a ("sys", "wl") mesh.

    Policy: the workload dim takes the largest divisor of W that also
    divides the device count (traces shard without padding); the system
    dim takes the remaining devices, capped at S (an 8-device host never
    runs a 2-system ladder 4x redundantly).  The system axis is then
    padded up to a ``sys_dim`` multiple — divisibility of S is never
    required.  ``force=(sys, wl)`` overrides the factorization (the
    ``--mesh`` debug flag); ``n_devices`` defaults to the visible device
    count.  Empty grids are rejected up front: a sweep over zero systems
    or zero workloads is always a caller bug, and letting it reach the
    mesh reshape would produce an unrelated error.
    """
    if n_systems <= 0:
        raise ValueError(
            f"empty ladder: no systems to simulate (n_systems={n_systems})")
    if n_workloads <= 0:
        raise ValueError(
            f"empty ladder: no workloads to simulate "
            f"(n_workloads={n_workloads})")
    if force is not None:
        sys_dim, wl_dim = int(force[0]), int(force[1])
        if sys_dim < 1 or wl_dim < 1:
            raise ValueError(f"mesh dims must be >= 1, got {force}")
        if n_workloads % wl_dim != 0:
            raise ValueError(
                f"mesh wl dim {wl_dim} does not divide the workload axis "
                f"({n_workloads}); traces are never padded — pick a "
                f"divisor (the system axis is the padded one)")
    else:
        d = n_devices if n_devices is not None else jax.local_device_count()
        wl_dim = max(k for k in range(1, min(d, n_workloads) + 1)
                     if n_workloads % k == 0 and d % k == 0)
        sys_dim = min(d // wl_dim, n_systems)
    pad = math.ceil(n_systems / sys_dim) * sys_dim
    return MeshPlan(sys_dim=sys_dim, wl_dim=wl_dim, n_systems=n_systems,
                    n_workloads=n_workloads, pad_systems=pad)


def build_mesh(plan: MeshPlan) -> Mesh:
    """Materialize the plan over the first ``plan.n_devices`` devices."""
    devs = jax.devices()
    if len(devs) < plan.n_devices:
        raise ValueError(
            f"mesh {plan.describe()} needs {plan.n_devices} devices but "
            f"only {len(devs)} are visible")
    grid = np.asarray(devs[: plan.n_devices]).reshape(
        plan.sys_dim, plan.wl_dim)
    return Mesh(grid, (AXIS_SYS, AXIS_WL))


def _pad_sys(x: jax.Array, pad: int) -> jax.Array:
    # replicate the last lane: a valid config, so padded lanes simulate
    # harmlessly (their outputs are sliced off, never stored)
    return jnp.concatenate(
        [x, jnp.broadcast_to(x[-1:], (pad,) + x.shape[1:])])


def shard_wrap(fn, plan: MeshPlan):
    """Wrap ``fn`` for the mesh ONCE; returns ``call(dyns, traces)``.

    ``fn`` is a per-block function: Dyn leaves arrive ``[S_blk]``-shaped
    and trace leaves ``[T, W_blk, ...]``; every output leaf must lead
    with ``[S_blk, W_blk]``.  The system axis is padded to the mesh (see
    ``plan_mesh``) and sliced back before returning, so callers always
    see exactly [S, W] outputs.  ``check_rep=False`` where the jax
    version still takes it: the body carries no collectives, so there
    are no replication claims to verify.

    The shard_map + jit wrapper is built here, outside the returned
    closure: same-shape calls (``run_ladder``'s fixed-width chunks) hit
    one jit cache entry and trace/lower exactly once.
    """
    mesh = build_mesh(plan)
    specs = dict(in_specs=(P(AXIS_SYS), P(None, AXIS_WL)),
                 out_specs=P(AXIS_SYS, AXIS_WL))
    try:
        sharded = shard_map(fn, mesh=mesh, check_rep=False, **specs)
    except TypeError:  # newer jax dropped/renamed check_rep
        sharded = shard_map(fn, mesh=mesh, **specs)
    jitted = jax.jit(sharded)

    def call(dyns, traces):
        S = jax.tree.leaves(dyns)[0].shape[0]
        W = jax.tree.leaves(traces)[0].shape[1]
        if (plan.n_systems, plan.n_workloads) != (S, W):
            raise ValueError(
                f"mesh plan is for a {plan.n_systems}x{plan.n_workloads} "
                f"grid but the inputs are {S}x{W}")
        pad = plan.pad_systems - S
        if pad:
            dyns = jax.tree.map(lambda x: _pad_sys(x, pad), dyns)
        dyns = jax.device_put(dyns, NamedSharding(mesh, P(AXIS_SYS)))
        traces = jax.device_put(traces,
                                NamedSharding(mesh, P(None, AXIS_WL)))
        out = jitted(dyns, traces)
        if pad:
            out = jax.tree.map(lambda x: x[:S], out)
        return out

    return call


def shard_systems(fn, dyns, traces, plan: MeshPlan | None = None):
    """One-shot form of ``shard_wrap``: plan (if needed), wrap, call."""
    S = jax.tree.leaves(dyns)[0].shape[0]
    W = jax.tree.leaves(traces)[0].shape[1]
    return shard_wrap(fn, plan or plan_mesh(S, W))(dyns, traces)
