"""Device-mesh planning + shard_map dispatch for batched ladder sweeps.

A batched ladder run is an [S]-system x [W]-workload grid of mutually
independent scans (``mmu.simulate_systems``).  This module spreads that
grid over a 2-D ``("sys", "wl")`` device mesh:

- ``plan_mesh`` factorizes the visible devices into mesh dims.  The
  workload dim must divide W exactly (traces are big; we never pad
  them here — ``runner.run_ladder`` fixes W via chunking instead); the
  system dim may be anything, because ``shard_systems`` PADS the system
  axis up to a mesh multiple — "S divides the device count evenly" is
  NOT a precondition.
- ``shard_systems`` places the inputs (``NamedSharding``: Dyn leaves
  ``P("sys")``, trace leaves ``P(None, "wl")``), wraps the caller's
  per-block function in ``shard_map`` and slices the padding back off.
  On a 1x1 mesh the same code path degenerates to an identity
  partitioning of a plain jitted call, so single-device hosts (CI)
  exercise the exact production code.

Every (s, w) lane's computation is independent and elementwise per
lane, so the mesh factorization cannot change results: a sharded run is
bit-identical to the unsharded one (pinned by tests/test_parallel.py
and the multidev CI job).

This module deliberately imports nothing from ``repro.core`` or its
``repro.sim`` siblings — it is a pure pytree/mesh utility, so the core
layer (``mmu.simulate_systems``) may import it without a cycle.
(``repro.obs`` is a stdlib-only leaf below even this layer, so emitting
trace events is cycle-safe.)
"""
from __future__ import annotations

import dataclasses
import functools
import math

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

import repro.obs as obs

try:  # jax >= 0.6 promotes shard_map out of experimental
    from jax import shard_map  # type: ignore[attr-defined]
except ImportError:
    from jax.experimental.shard_map import shard_map

AXIS_SYS = "sys"
AXIS_WL = "wl"
AXIS_CORE = "core"
AXIS_T = "t"
AXIS_LANE = "lane"

__all__ = ["AXIS_SYS", "AXIS_WL", "AXIS_CORE", "AXIS_T", "AXIS_LANE",
           "MeshPlan", "plan_mesh", "build_mesh", "shard_wrap",
           "shard_systems", "pick_t_shards", "time_shard_scan",
           "plan_lane_dim", "shard_lanes"]


@dataclasses.dataclass(frozen=True)
class MeshPlan:
    """A (sys x wl [x core]) device-mesh factorization of a sweep grid.

    ``core_dim > 1`` adds a third mesh axis over the per-core trace
    lanes of a multicore run ([T, W, C] traces); ``core_dim == 1``
    (every single-core plan) keeps the exact 2-D mesh of before — the
    core axis, when present, then runs as an inner vmap lane instead.
    """

    sys_dim: int       # mesh extent along the system axis
    wl_dim: int        # mesh extent along the workload axis (divides W)
    n_systems: int     # unpadded S
    n_workloads: int   # W
    pad_systems: int   # S padded up to a sys_dim multiple
    core_dim: int = 1  # mesh extent along the core axis (divides C)
    n_cores: int = 1   # C (1 = single-core: traces have no core axis)

    @property
    def n_devices(self) -> int:
        return self.sys_dim * self.wl_dim * self.core_dim

    def describe(self) -> str:
        if self.core_dim > 1:
            return f"{self.sys_dim}x{self.wl_dim}x{self.core_dim}"
        return f"{self.sys_dim}x{self.wl_dim}"


def plan_mesh(n_systems: int, n_workloads: int, n_devices: int | None = None,
              force: tuple[int, ...] | None = None,
              n_cores: int = 1) -> MeshPlan:
    """Factorize the device count into a ("sys", "wl"[, "core"]) mesh.

    Policy: the workload dim takes the largest divisor of W that also
    divides the device count (traces shard without padding); the system
    dim takes the remaining devices, capped at S (an 8-device host never
    runs a 2-system ladder 4x redundantly).  The system axis is then
    padded up to a ``sys_dim`` multiple — divisibility of S is never
    required.  ``force=(sys, wl)`` or ``(sys, wl, core)`` overrides the
    factorization (the ``--mesh`` debug flag); ``n_devices`` defaults to
    the visible device count.  ``n_cores > 1`` declares a multicore run
    ([T, W, C] traces): the core axis defaults to an inner vmap lane
    (``core_dim=1``), and a 3-tuple ``force`` promotes it to a third
    mesh dim (``core_dim`` must divide C exactly — core lanes, like
    workloads, are never padded).  Empty grids are rejected up front: a
    sweep over zero systems or zero workloads is always a caller bug,
    and letting it reach the mesh reshape would produce an unrelated
    error.
    """
    if n_systems <= 0:
        raise ValueError(
            f"empty ladder: no systems to simulate (n_systems={n_systems})")
    if n_workloads <= 0:
        raise ValueError(
            f"empty ladder: no workloads to simulate "
            f"(n_workloads={n_workloads})")
    if n_cores < 1:
        raise ValueError(f"n_cores must be >= 1, got {n_cores}")
    core_dim = 1
    if force is not None:
        if len(force) not in (2, 3):
            raise ValueError(
                f"mesh force must be (sys, wl) or (sys, wl, core), "
                f"got {force}")
        sys_dim, wl_dim = int(force[0]), int(force[1])
        core_dim = int(force[2]) if len(force) == 3 else 1
        if sys_dim < 1 or wl_dim < 1 or core_dim < 1:
            raise ValueError(f"mesh dims must be >= 1, got {force}")
        if n_workloads % wl_dim != 0:
            raise ValueError(
                f"mesh wl dim {wl_dim} does not divide the workload axis "
                f"({n_workloads}); traces are never padded — pick a "
                f"divisor (the system axis is the padded one)")
        if core_dim > 1 and n_cores % core_dim != 0:
            raise ValueError(
                f"mesh core dim {core_dim} does not divide the core axis "
                f"({n_cores}); core lanes are never padded — pick a "
                f"divisor")
    else:
        d = n_devices if n_devices is not None else jax.local_device_count()
        wl_dim = max(k for k in range(1, min(d, n_workloads) + 1)
                     if n_workloads % k == 0 and d % k == 0)
        sys_dim = min(d // wl_dim, n_systems)
    pad = math.ceil(n_systems / sys_dim) * sys_dim
    return MeshPlan(sys_dim=sys_dim, wl_dim=wl_dim, n_systems=n_systems,
                    n_workloads=n_workloads, pad_systems=pad,
                    core_dim=core_dim, n_cores=n_cores)


def build_mesh(plan: MeshPlan) -> Mesh:
    """Materialize the plan over the first ``plan.n_devices`` devices."""
    devs = jax.devices()
    if len(devs) < plan.n_devices:
        raise ValueError(
            f"mesh {plan.describe()} needs {plan.n_devices} devices but "
            f"only {len(devs)} are visible")
    if plan.core_dim > 1:
        grid = np.asarray(devs[: plan.n_devices]).reshape(
            plan.sys_dim, plan.wl_dim, plan.core_dim)
        return Mesh(grid, (AXIS_SYS, AXIS_WL, AXIS_CORE))
    grid = np.asarray(devs[: plan.n_devices]).reshape(
        plan.sys_dim, plan.wl_dim)
    return Mesh(grid, (AXIS_SYS, AXIS_WL))


def _pad_sys(x: jax.Array, pad: int) -> jax.Array:
    # replicate the last lane: a valid config, so padded lanes simulate
    # harmlessly (their outputs are sliced off, never stored)
    return jnp.concatenate(
        [x, jnp.broadcast_to(x[-1:], (pad,) + x.shape[1:])])


def shard_wrap(fn, plan: MeshPlan):
    """Wrap ``fn`` for the mesh ONCE; returns ``call(dyns, traces)``.

    ``fn`` is a per-block function: Dyn leaves arrive ``[S_blk]``-shaped
    and trace leaves ``[T, W_blk, ...]``; every output leaf must lead
    with ``[S_blk, W_blk]``.  The system axis is padded to the mesh (see
    ``plan_mesh``) and sliced back before returning, so callers always
    see exactly [S, W] outputs.  ``check_rep=False`` where the jax
    version still takes it: the body carries no collectives, so there
    are no replication claims to verify.

    The shard_map + jit wrapper is built here, outside the returned
    closure: same-shape calls (``run_ladder``'s fixed-width chunks) hit
    one jit cache entry and trace/lower exactly once.
    """
    mesh = build_mesh(plan)
    if plan.core_dim > 1:
        # multicore 3-D mesh: trace leaves are [T, W, C] and every
        # output leaf leads with [S_blk, W_blk, C_blk]
        trace_spec = P(None, AXIS_WL, AXIS_CORE)
        out_spec = P(AXIS_SYS, AXIS_WL, AXIS_CORE)
    else:
        # single-core (or inner-vmap core lanes): the exact 2-D specs
        # of before; a trailing core axis, if any, stays replicated
        trace_spec = P(None, AXIS_WL)
        out_spec = P(AXIS_SYS, AXIS_WL)
    specs = dict(in_specs=(P(AXIS_SYS), trace_spec), out_specs=out_spec)
    try:
        sharded = shard_map(fn, mesh=mesh, check_rep=False, **specs)
    except TypeError:  # newer jax dropped/renamed check_rep
        sharded = shard_map(fn, mesh=mesh, **specs)
    jitted = jax.jit(sharded)

    def call(dyns, traces):
        S = jax.tree.leaves(dyns)[0].shape[0]
        W = jax.tree.leaves(traces)[0].shape[1]
        if (plan.n_systems, plan.n_workloads) != (S, W):
            raise ValueError(
                f"mesh plan is for a {plan.n_systems}x{plan.n_workloads} "
                f"grid but the inputs are {S}x{W}")
        pad = plan.pad_systems - S
        if pad:
            dyns = jax.tree.map(lambda x: _pad_sys(x, pad), dyns)
        dyns = jax.device_put(dyns, NamedSharding(mesh, P(AXIS_SYS)))
        traces = jax.device_put(traces, NamedSharding(mesh, trace_spec))
        out = jitted(dyns, traces)
        if pad:
            out = jax.tree.map(lambda x: x[:S], out)
        return out

    return call


def pick_t_shards(n: int, requested: int) -> int:
    """Largest divisor of the trace length ``n`` that is <= ``requested``.

    Time blocks must tile the trace exactly — padding the time axis
    would simulate phantom accesses and break bit-identity with the
    serial scan — so a requested shard count that does not divide ``n``
    is rounded DOWN to the nearest divisor (worst case 1: no sharding).
    """
    if n <= 0:
        raise ValueError(f"cannot time-shard an empty trace (n={n})")
    if requested < 1:
        raise ValueError(f"time-shard count must be >= 1, got {requested}")
    return max(t for t in range(1, min(requested, n) + 1) if n % t == 0)


def _block_eq(a, b, t: int) -> jax.Array:
    """Per-block (leading axis ``t``) bitwise equality of two pytrees."""
    eqs = jax.tree.map(
        lambda x, y: jnp.all((x == y).reshape(t, -1), axis=1), a, b)
    return functools.reduce(jnp.logical_and, jax.tree.leaves(eqs))


def time_shard_scan(block_fn, st0, trace, t_shards: int,
                    batch: str = "vmap"):
    """Run ``block_fn`` over ``t_shards`` trace blocks speculatively and
    resolve the carry hand-off to the exact serial result.

    ``block_fn(state, trace_block) -> state`` is one serial segment of
    the access scan (any backend).  The trace's time axis is split into
    ``t`` contiguous blocks; every block starts from a GUESSED carry
    (cold ``st0`` in round 1) and all blocks run in parallel — on a
    multi-device host the block axis is laid out on a 1-D ``("t",)``
    mesh, so single-trace latency scales with devices.  After each
    round the hand-off chain is re-seeded (``start[i+1] = end[i]``) and
    re-run until a fixed point: block 0's start is exact by definition,
    and block ``i``'s end is exact once its start matched the exact end
    of block ``i-1``.  The exact-known prefix grows by >= 1 block per
    round, so the loop terminates in <= ``t`` rounds and the returned
    state is BIT-IDENTICAL to the serial scan.  Feedback-heavy MMU
    state (``now``, pressure/MPKI counters) makes a cold guess almost
    never coincide with the true carry, so realistic convergence IS the
    worst case ``t`` rounds — the win is latency (each round is ``n/t``
    long on ``t`` devices), not total work.

    ``batch="vmap"`` runs blocks via ``jax.vmap``; ``batch="map"``
    (required for the pallas backend, whose grid seeding must not be
    rewritten by vmap batching) uses sequential ``lax.map``.

    Returns ``(final_state, info)`` with ``info = {"t_shards", "rounds",
    "requested"}``; ``t_shards`` is the requested count rounded down to
    a divisor of the trace length (see ``pick_t_shards``).
    """
    if batch not in ("vmap", "map"):
        raise ValueError(f"unknown batch mode {batch!r}")
    n = jax.tree.leaves(trace)[0].shape[0]
    t = pick_t_shards(n, t_shards)
    if t == 1:
        return block_fn(st0, trace), {
            "t_shards": 1, "rounds": 1, "requested": int(t_shards)}

    blocks = jax.tree.map(
        lambda x: x.reshape((t, n // t) + x.shape[1:]), trace)
    starts = jax.tree.map(
        lambda x: jnp.broadcast_to(x, (t,) + x.shape), st0)

    d = jax.local_device_count()
    if batch == "vmap" and d > 1:
        g = max(k for k in range(1, min(d, t) + 1) if t % k == 0)
        if g > 1:
            mesh = Mesh(np.asarray(jax.devices()[:g]), (AXIS_T,))
            sh = NamedSharding(mesh, P(AXIS_T))
            blocks = jax.device_put(blocks, sh)
            starts = jax.device_put(starts, sh)

    @jax.jit
    def round_fn(starts, blocks):
        if batch == "vmap":
            ends = jax.vmap(block_fn)(starts, blocks)
        else:
            ends = jax.lax.map(lambda ab: block_fn(*ab), (starts, blocks))
        new_starts = jax.tree.map(
            lambda s0, e: jnp.concatenate([s0[None], e[:-1]]), st0, ends)
        return ends, new_starts, _block_eq(new_starts, starts, t)

    rounds = 0
    known = 0
    while known < t:
        ends, new_starts, eq = round_fn(starts, blocks)
        rounds += 1
        eq = np.asarray(jax.device_get(eq))
        # ends[0] came from the true st0, so it is exact; end i is exact
        # iff its start was, i.e. iff the start we USED equals the exact
        # end of block i-1 (eq[i]) and that end itself is exact
        known = 1
        while known < t and eq[known]:
            known += 1
        starts = new_starts
        # per-round hand-off telemetry: how far the exact prefix grew
        obs.event(obs.names.EV_TIME_SHARD_ROUND, round=rounds,
                  known_prefix=int(known), t_shards=t)
    final = jax.tree.map(lambda e: e[-1], ends)
    return final, {"t_shards": t, "rounds": rounds,
                   "requested": int(t_shards)}


def shard_systems(fn, dyns, traces, plan: MeshPlan | None = None):
    """One-shot form of ``shard_wrap``: plan (if needed), wrap, call."""
    S = jax.tree.leaves(dyns)[0].shape[0]
    W = jax.tree.leaves(traces)[0].shape[1]
    return shard_wrap(fn, plan or plan_mesh(S, W))(dyns, traces)


def plan_lane_dim(n_lanes: int, n_devices: int | None = None) -> int:
    """Mesh extent for a 1-D ``("lane",)`` mesh over ``n_lanes`` lanes.

    Largest divisor of ``n_lanes`` that fits the visible device count —
    lanes, like workloads, are never padded (each lane is an independent
    engine whose state must round-trip bit-exactly).  1 device → 1 (the
    identity partitioning).
    """
    if n_lanes < 1:
        raise ValueError(f"n_lanes must be >= 1, got {n_lanes}")
    d = n_devices if n_devices is not None else jax.local_device_count()
    if d < 1:
        raise ValueError(f"n_devices must be >= 1, got {d}")
    return max(k for k in range(1, min(d, n_lanes) + 1) if n_lanes % k == 0)


def shard_lanes(fn, n_lanes: int, n_devices: int | None = None):
    """Wrap a per-lane-batch ``fn`` for a 1-D ``("lane",)`` device mesh.

    The serving load harness's mesh: every pytree argument and output of
    ``fn`` leads with the lane axis ``[L, ...]`` (one engine per lane —
    its slot pool, KV page pool, and VTC all ride that leading axis), so
    sharding lane-batched state splits the slot and page pools across
    the device mesh.  ``fn`` is typically ``jax.vmap`` of a single-lane
    step; inside ``shard_map`` each device sees its ``[L/dim, ...]``
    block.  As with ``shard_wrap``, the jit(shard_map) wrapper is built
    ONCE here so every same-shape call hits one jit-cache entry, and a
    1-device host runs the identical code path as an identity
    partitioning.

    Returns ``call(*args)`` with attribute ``mesh_dim`` (the lane-mesh
    extent actually used).  Lanes must stay divisible: ``n_lanes`` is
    never padded, so the mesh dim comes from ``plan_lane_dim``.
    """
    dim = plan_lane_dim(n_lanes, n_devices)
    mesh = Mesh(np.asarray(jax.devices()[:dim]), (AXIS_LANE,))
    spec = P(AXIS_LANE)
    try:
        sharded = shard_map(fn, mesh=mesh, in_specs=spec, out_specs=spec,
                            check_rep=False)
    except TypeError:  # newer jax dropped/renamed check_rep
        sharded = shard_map(fn, mesh=mesh, in_specs=spec, out_specs=spec)
    jitted = jax.jit(sharded)
    sharding = NamedSharding(mesh, spec)

    def call(*args):
        args = jax.tree.map(
            lambda x: jax.device_put(jnp.asarray(x), sharding), args)
        return jitted(*args)

    call.mesh_dim = dim
    return call
