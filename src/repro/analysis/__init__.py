"""Static-analysis subsystem: the repo's invariants as checkable passes.

Five passes (see ``python -m repro.analysis --list``):

- ``contracts`` — stage-contract checker (C00x): signatures, gating
  tables, sized-1-when-off state, Stats fold/surface discipline.
- ``lint`` — tracer-hygiene AST rules (TH00x) + Pallas resident-state
  checks (PL00x).
- ``jaxpr`` — jaxpr-equivalence over every discovered ladder family
  (JX00x): proves dyn-gating yields ONE compile, abstract-trace only
  (no device execution).
- ``obs`` — observability contract (OB001): every BENCH_sweep schema-5
  field is derivable from a span/counter source the instrumentation
  actually emits, and ``runner.LADDER_PERF`` records come only from
  ``obs.report.fill_record`` (no orphan hand-set fields).
- ``recompile`` — executes a tiny ladder fill and bounds the actual
  ``run_systems`` compile count (RC001).  Runs the simulator, so it is
  opt-in from the CLI and wired into tier-1 via the test suite.

``run_static()`` is the no-execution subset CI runs before the
compile-heavy jobs.
"""
from repro.analysis import (contracts, jaxpr_equiv, lint, obs_contract,
                            recompile)

PASSES = ("contracts", "lint", "jaxpr", "obs", "recompile")
STATIC_PASSES = ("contracts", "lint", "jaxpr", "obs")


def run_pass(name: str, progress=None) -> list:
    if name == "contracts":
        return contracts.run()
    if name == "lint":
        return lint.run()
    if name == "jaxpr":
        _, findings = jaxpr_equiv.check_all(progress=progress)
        return findings
    if name == "obs":
        return obs_contract.run()
    if name == "recompile":
        return recompile.check_ladder_dispatch()
    raise ValueError(f"unknown analysis pass {name!r} (know {PASSES})")


def run_static(progress=None) -> list:
    """All passes that neither execute nor compile anything."""
    findings = []
    for p in STATIC_PASSES:
        findings += run_pass(p, progress=progress)
    return findings


__all__ = ["PASSES", "STATIC_PASSES", "contracts", "jaxpr_equiv", "lint",
           "obs_contract", "recompile", "run_pass", "run_static"]
