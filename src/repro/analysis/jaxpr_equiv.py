"""jaxpr-equivalence pass: prove every ladder family is one-compile.

A ladder family batches into one vmapped ``simulate_systems`` compile
*only if* every member's per-access step traces to the same computation
graph — i.e. all config differences flow through traced ``Dyn`` values,
never through Python control flow.  A single ``if cfg_dependent:`` or
``int(tracer)`` silently splits the family into per-member compiles with
no functional test failing.

This pass traces ``mmu.make_step`` for every member of every
``discover_ladders()`` family with that member's *concrete* dyn closed
over (exactly the divergence-sensitive configuration: a Python branch
on a dyn value produces a structurally different jaxpr, while correct
gating produces jaxprs identical up to constant values).  Each jaxpr is
canonicalized — serial variable renaming, recursive canonicalization of
nested jaxprs in eqn params — and compared line-by-line against the
family's first member; on mismatch the finding names the first
diverging equation and its primitives on both sides.

Tracing uses ``jax.make_jaxpr`` over ``ShapeDtypeStruct`` state/access
pytrees, so no device buffers are allocated and nothing executes: the
pass is safe for lint-tier CI.  A second, cheap sub-check traces each
family's step once with *abstract* dyn (dyn as a traced argument, the
shape the real batched dispatch sees) so any ``int(tracer)``-style
concretization inside stage code surfaces as a named finding instead
of a deep stack trace at sweep time.
"""
from __future__ import annotations

from dataclasses import dataclass, field


def _core():
    """jax core types across the 0.4.x reorganizations."""
    import jax

    try:  # jax >= 0.4.33
        from jax.extend import core as jex_core
        return jex_core.Jaxpr, jex_core.ClosedJaxpr, jex_core.Literal
    except (ImportError, AttributeError):
        return jax.core.Jaxpr, jax.core.ClosedJaxpr, jax.core.Literal


def _aval_str(aval) -> str:
    dtype = getattr(aval, "dtype", None)
    shape = getattr(aval, "shape", ())
    return f"{getattr(dtype, 'name', dtype)}[{','.join(map(str, shape))}]"


def canonicalize(jaxpr) -> list:
    """Canonical per-equation lines for a (Closed)Jaxpr.

    Variables are renamed serially in first-use order; nested jaxprs in
    eqn params (scan/cond/custom_jvp bodies) are canonicalized
    recursively; literal *values* are kept (members of a correctly
    gated family share the same base config, so their literals agree —
    only closed-over consts, which appear as constvars here, may
    differ).  Returns ``[(primitive_name, line), ...]`` with a final
    ``("return", ...)`` entry.
    """
    Jaxpr, ClosedJaxpr, Literal = _core()
    jx = jaxpr.jaxpr if isinstance(jaxpr, ClosedJaxpr) else jaxpr

    env: dict = {}

    def name(v) -> str:
        if isinstance(v, Literal):
            return f"lit({v.val!r}):{_aval_str(v.aval)}"
        if v not in env:
            env[v] = f"v{len(env)}"
        return f"{env[v]}:{_aval_str(v.aval)}"

    def param(v) -> str:
        if isinstance(v, (Jaxpr, ClosedJaxpr)):
            return "jaxpr{" + ";".join(ln for _, ln in canonicalize(v)) + "}"
        if isinstance(v, (tuple, list)):
            return "(" + ",".join(param(x) for x in v) + ")"
        if isinstance(v, dict):
            return ("{" + ",".join(f"{k}:{param(x)}"
                                   for k, x in sorted(v.items())) + "}")
        if callable(v):
            return getattr(v, "__name__", type(v).__name__)
        return repr(v)

    lines = []
    for v in jx.constvars:
        name(v)
    for v in jx.invars:
        name(v)
    for eqn in jx.eqns:
        params = ",".join(f"{k}={param(v)}"
                          for k, v in sorted(eqn.params.items()))
        outs = " ".join(name(o) for o in eqn.outvars)
        ins = " ".join(name(i) for i in eqn.invars)
        lines.append((eqn.primitive.name,
                      f"{outs} = {eqn.primitive.name}[{params}] {ins}"))
    lines.append(("return", "return " + " ".join(name(v)
                                                 for v in jx.outvars)))
    return lines


def _structs(cfg=None):
    """ShapeDtypeStruct pytrees for (state, access-record) tracing.

    Multicore configs see an extra ``core`` lane-id leaf, exactly as
    the real multiprogrammed-mix dispatch supplies it — the traced
    graph must match what the batched sweep actually compiles."""
    import jax
    import jax.numpy as jnp

    from repro.sim import trace_gen

    g = trace_gen.generate("rnd", n=8, seed=0)
    acc = {k: jax.ShapeDtypeStruct((), jnp.asarray(v[:1]).dtype)
           for k, v in g["trace"].items()}
    acc["ipa"] = jax.ShapeDtypeStruct((), jnp.float32)
    if cfg is not None and cfg.n_cores > 1:
        acc["core"] = jax.ShapeDtypeStruct((), jnp.int32)
    return acc


def _state_struct(cfg):
    import jax

    from repro.core.stages import make_state

    return jax.eval_shape(lambda: make_state(cfg))


def member_jaxpr(base_cfg, dyn, stage_names=None):
    """Trace one family member's per-access step (concrete dyn closed
    over) without executing it; returns a ClosedJaxpr."""
    import jax

    from repro.core import mmu

    step = mmu.make_step(base_cfg, stage_names, dyn=dyn)
    return jax.make_jaxpr(step)(_state_struct(base_cfg), _structs(base_cfg))


def diff_canonical(ref_name, ref_lines, name, lines) -> str | None:
    """First structural divergence between two canonical jaxprs, or
    None when alpha-equivalent.  Names the diverging primitive."""
    n = min(len(ref_lines), len(lines))
    for i in range(n):
        if ref_lines[i] != lines[i]:
            pa, la = ref_lines[i]
            pb, lb = lines[i]
            return (f"members '{ref_name}' and '{name}' diverge at eqn "
                    f"{i}/{max(len(ref_lines), len(lines))}: primitive "
                    f"'{pa}' vs '{pb}'\n      {ref_name}: {la[:160]}\n"
                    f"      {name}: {lb[:160]}")
    if len(ref_lines) != len(lines):
        longer, which = ((ref_lines, ref_name)
                         if len(ref_lines) > len(lines) else (lines, name))
        extra = [p for p, _ in longer[n:]][:8]
        return (f"members '{ref_name}' ({len(ref_lines)} eqns) and "
                f"'{name}' ({len(lines)} eqns) differ in length; extra "
                f"primitives on '{which}': {extra}")
    return None


@dataclass
class FamilyReport:
    family: str
    members: list
    n_members: int = 0
    n_eqns: int = 0
    equivalent: bool = False
    findings: list = field(default_factory=list)


def check_family(fam_name: str, members, progress=None) -> FamilyReport:
    """Prove (or refute, with a named primitive) one-compile for one
    discovered ladder family."""
    from repro.core.stages import Dyn, dyn_of
    from repro.sim import systems

    members = list(members)
    rep = FamilyReport(family=fam_name, members=members,
                       n_members=len(members))
    base_cfg = systems.ladder_base_config(members=members)

    ref_name = None
    ref_lines = None
    for m in members:
        if progress:
            progress(f"  tracing {fam_name}/{m}")
        dyn = dyn_of(systems.config(m))
        try:
            lines = canonicalize(member_jaxpr(base_cfg, dyn))
        except Exception as e:  # a member that cannot trace at all
            rep.findings.append(
                f"JX002 family '{fam_name}': member '{m}' failed to "
                f"trace abstractly: {type(e).__name__}: {e}")
            continue
        if ref_lines is None:
            ref_name, ref_lines = m, lines
            rep.n_eqns = len(lines)
            continue
        d = diff_canonical(ref_name, ref_lines, m, lines)
        if d is not None:
            rep.findings.append(
                f"JX001 family '{fam_name}' is NOT one-compile: {d}")

    # abstract-dyn trace: the batched dispatch's view (dyn is a traced
    # argument) — catches int(tracer)/if-on-dyn concretization loudly
    import jax
    import jax.numpy as jnp

    dyn0 = dyn_of(base_cfg)
    dyn_struct = Dyn(*[jax.ShapeDtypeStruct(jnp.shape(v), jnp.asarray(v).dtype)
                       for v in dyn0])
    try:
        from repro.core import mmu

        jax.eval_shape(
            lambda st, acc, dd: mmu.make_step(base_cfg, None, dyn=dd)(st, acc),
            _state_struct(base_cfg), _structs(base_cfg), dyn_struct)
    except Exception as e:
        rep.findings.append(
            f"JX003 family '{fam_name}': step does not trace with "
            f"abstract Dyn (a stage concretizes a traced value): "
            f"{type(e).__name__}: {e}")

    rep.equivalent = not rep.findings
    return rep


def check_all(progress=None):
    """Run the pass over every discovered family.

    Returns ``(reports, findings)`` where findings is a flat list of
    human-readable violation strings (empty = all families one-compile).
    """
    from repro.sim import systems

    reports = []
    findings = []
    for fam, members in sorted(systems.discover_ladders().items()):
        rep = check_family(fam, members, progress=progress)
        reports.append(rep)
        findings.extend(rep.findings)
    return reports, findings


def family_metadata() -> dict:
    """Cheap (trace-free) family metadata for perf artifacts:
    ``{family: {"n_members": int, "members": [...]}}``."""
    from repro.sim import systems

    return {fam: {"n_members": len(members), "members": sorted(members)}
            for fam, members in systems.discover_ladders().items()}
