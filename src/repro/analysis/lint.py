"""Tracer-hygiene lint: AST rules over stage/kernel code.

Inside ``make_step``'s composition everything downstream of the state,
request, and Dyn pytrees is a jax tracer; Python-level decisions on
those values either crash at trace time or — worse — silently
specialize the compile on one member's value and split the one-compile
family.  These rules flag the patterns *statically*, before any trace:

- TH001 ``int()``/``float()``/``bool()`` on a traced value (concretizes
  the tracer; under abstract dyn this is a ConcretizationTypeError, and
  under concrete dyn it silently bakes one member's value into the
  graph).
- TH002 ``if``/``while``/``assert``/ternary on a traced value
  (Python control flow forks the traced graph per member; use
  ``jnp.where``/``lax.cond``).  Structure tests are exempt:
  ``x is None`` / ``name in out`` are pytree-level, not value-level.
- TH003 ``np.*`` calls on traced values (silently falls back to host
  numpy, concretizing; use ``jnp``).
- TH004 Python iteration directly over a traced pytree/array (e.g.
  ``for v in req.dyn``): loops over traced values unroll or crash.
  Only *direct* iteration over a traced parameter (or an
  attribute/subscript chain on one) is flagged — iterating a Python
  list of tracers (``jax.tree.leaves(...)``) is legitimate.

Taint model: function parameters with conventional traced names
(``st``, ``req``, ``out``, ``acc``, ``dyn``, ...) are roots; locals
assigned from tainted expressions inherit taint in statement order.
Reads of static metadata (``.shape``/``.ndim``/``.dtype``/``.size``)
break the taint — shapes are Python values even on tracers.

A separate structural check (PL00x) pins the resident-state discipline
of the Pallas kernel in ``kernels/mmu_step.py``:

- PL001 every BlockSpec feeding ``out_specs`` (the resident state) has
  a constant ``index_map`` (ignores the grid index) — state must alias
  the same buffer across grid steps;
- PL002 ``pallas_call`` passes no ``input_output_aliases`` (state flows
  init_refs -> out_refs through the explicit step-0 seed; aliasing
  would silently break the speculative time-shard replay);
- PL003 the kernel seeds its resident outputs at grid step 0
  (``@pl.when(pl.program_id(0) == 0)``).
"""
from __future__ import annotations

import ast
from pathlib import Path

SRC = Path(__file__).resolve().parents[1]

DEFAULT_FILES = (
    *sorted((SRC / "core" / "stages").glob("*.py")),
    SRC / "core" / "mmu.py",
    SRC / "kernels" / "mmu_step.py",
)
PALLAS_FILE = SRC / "kernels" / "mmu_step.py"

# parameter names that conventionally carry traced pytrees in stage /
# step / kernel code (see the stage contract in core/stages/base.py)
TRACED_PARAMS = frozenset({
    "st", "st0", "req", "need", "out", "acc", "ss", "dyn", "dd", "dyns",
    "walk_res", "s0", "trace", "traces", "tr", "consts", "carry", "state",
})

# attribute reads that yield static Python values even on tracers
STATIC_ATTRS = frozenset({"shape", "ndim", "dtype", "size", "_fields",
                          "aval", "sharding"})


def _is_structure_test(node: ast.expr) -> bool:
    """``x is None`` / ``k in out`` — pytree-structure tests, exempt."""
    return (isinstance(node, ast.Compare)
            and all(isinstance(op, (ast.Is, ast.IsNot, ast.In, ast.NotIn))
                    for op in node.ops))


class _FunctionLint:
    def __init__(self, path_name: str, findings: list):
        self.path = path_name
        self.findings = findings
        self.env: set = set()
        self.param_roots: frozenset = frozenset()

    # ---- taint

    def tainted(self, node) -> bool:
        if isinstance(node, ast.Attribute) and node.attr in STATIC_ATTRS:
            return False
        if _is_structure_test(node):
            return False
        if isinstance(node, ast.Name):
            return node.id in self.env
        if isinstance(node, ast.Lambda):  # deferred body: not a value read
            return False
        return any(self.tainted(c) for c in ast.iter_child_nodes(node))

    def _direct_chain_root(self, node):
        """Name at the root of a pure attribute/subscript chain (no
        calls), else None."""
        while isinstance(node, (ast.Attribute, ast.Subscript)):
            node = node.value
        return node.id if isinstance(node, ast.Name) else None

    # ---- walk

    def run(self, fn: ast.FunctionDef):
        self.env = {a.arg for a in
                    (*fn.args.posonlyargs, *fn.args.args,
                     *fn.args.kwonlyargs)
                    if a.arg in TRACED_PARAMS}
        self.param_roots = frozenset(self.env)
        if not self.env:
            return
        for stmt in ast.walk(fn):
            self._check(stmt)

    def _taint_target(self, tgt):
        # taint only what the assignment binds/mutates: plain names, the
        # container of a subscript/attribute store — NEVER names inside
        # a subscript's index expression (out[stg.name] taints 'out',
        # not 'stg')
        if isinstance(tgt, ast.Name):
            self.env.add(tgt.id)
        elif isinstance(tgt, (ast.Tuple, ast.List)):
            for el in tgt.elts:
                self._taint_target(el)
        elif isinstance(tgt, (ast.Subscript, ast.Attribute, ast.Starred)):
            root = self._direct_chain_root(
                tgt.value if isinstance(tgt, ast.Starred) else tgt)
            if root is not None:
                self.env.add(root)

    def _flag(self, node, code, msg):
        self.findings.append(f"{code} {self.path}:{node.lineno}: {msg}")

    def _check(self, node):
        if isinstance(node, ast.Assign):
            if self.tainted(node.value):
                for tgt in node.targets:
                    self._taint_target(tgt)
        elif isinstance(node, ast.Call):
            fname = node.func.id if isinstance(node.func, ast.Name) else None
            if fname in ("int", "float", "bool") and any(
                    self.tainted(a) for a in node.args):
                self._flag(node, "TH001",
                           f"{fname}() on a traced value concretizes the "
                           f"tracer (splits the one-compile family / "
                           f"ConcretizationTypeError under vmapped Dyn)")
            root = (self._direct_chain_root(node.func)
                    if isinstance(node.func, ast.Attribute) else None)
            if root == "np" and any(self.tainted(a) for a in node.args):
                self._flag(node, "TH003",
                           "np.* on a traced value concretizes it on "
                           "host — use jnp")
        elif isinstance(node, (ast.If, ast.While)):
            if self.tainted(node.test):
                kw = "while" if isinstance(node, ast.While) else "if"
                self._flag(node, "TH002",
                           f"Python `{kw}` on a traced value forks the "
                           f"trace per member — use jnp.where/lax.cond")
        elif isinstance(node, ast.IfExp):
            if self.tainted(node.test):
                self._flag(node, "TH002",
                           "ternary on a traced value forks the trace "
                           "per member — use jnp.where")
        elif isinstance(node, ast.Assert):
            if self.tainted(node.test):
                self._flag(node, "TH002",
                           "assert on a traced value — use "
                           "checkify/debug.check or drop it")
        elif isinstance(node, (ast.For, ast.comprehension)):
            # narrow by design: only DIRECT iteration over a traced
            # parameter (or a call-free chain on one) — iterating a
            # Python list of tracers (tree.leaves, jaxpr consts) is fine
            it = node.iter
            root = self._direct_chain_root(it)
            if root is not None and root in self.param_roots:
                self._flag(it, "TH004",
                           f"Python loop directly over traced "
                           f"{root!r} (e.g. Dyn) unrolls/crashes — "
                           f"use jax.tree.map or traced ops")


def check_files(paths=None) -> list:
    """Tracer-hygiene lint over stage/kernel files; returns findings."""
    paths = [Path(p) for p in (paths or DEFAULT_FILES)]
    findings: list = []
    for path in paths:
        if path.name == "__init__.py":
            continue
        tree = ast.parse(path.read_text())
        for node in ast.walk(tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                _FunctionLint(path.name, findings).run(node)
    # nested defs are linted twice (own pass + enclosing pass): dedupe
    return list(dict.fromkeys(findings))


# ------------------------------------------------------- Pallas checks


def _lambda_ignores_grid_index(lam: ast.Lambda) -> bool:
    args = lam.args.args
    if not args:
        return True
    grid = args[0].arg
    return not any(isinstance(n, ast.Name) and n.id == grid
                   for n in ast.walk(lam.body))


def check_pallas(path=None) -> list:
    """Resident-state discipline of the blocked-scan Pallas kernel."""
    path = Path(path) if path else PALLAS_FILE
    tree = ast.parse(path.read_text())
    findings: list = []

    # classify spec-helper functions by their BlockSpec index_map lambda
    constant_helpers: set = set()
    blocked_helpers: set = set()
    for fn in ast.walk(tree):
        if not isinstance(fn, ast.FunctionDef):
            continue
        for call in ast.walk(fn):
            if (isinstance(call, ast.Call)
                    and isinstance(call.func, ast.Attribute)
                    and call.func.attr == "BlockSpec"):
                lam = next((a for a in call.args
                            if isinstance(a, ast.Lambda)), None)
                if lam is None:
                    continue
                if _lambda_ignores_grid_index(lam):
                    constant_helpers.add(fn.name)
                else:
                    blocked_helpers.add(fn.name)

    calls = [n for n in ast.walk(tree)
             if isinstance(n, ast.Call)
             and ((isinstance(n.func, ast.Attribute)
                   and n.func.attr == "pallas_call")
                  or (isinstance(n.func, ast.Name)
                      and n.func.id == "pallas_call"))]
    if not calls:
        return [f"PL001 {path.name}: no pallas_call found"]

    for call in calls:
        kw = {k.arg: k.value for k in call.keywords if k.arg}
        if "input_output_aliases" in kw:
            findings.append(
                f"PL002 {path.name}:{call.lineno}: pallas_call passes "
                f"input_output_aliases — resident state must flow "
                f"init_refs -> out_refs via the step-0 seed, not "
                f"aliasing (breaks the time-shard replay)")
        out_specs = kw.get("out_specs")
        if out_specs is None:
            findings.append(
                f"PL001 {path.name}:{call.lineno}: pallas_call has no "
                f"out_specs — resident state outputs must declare "
                f"constant-index_map BlockSpecs")
            continue
        used = {n.func.id for n in ast.walk(out_specs)
                if isinstance(n, ast.Call) and isinstance(n.func, ast.Name)}
        bad = used & blocked_helpers
        if bad:
            findings.append(
                f"PL001 {path.name}:{call.lineno}: out_specs uses "
                f"grid-indexed BlockSpec helper(s) {sorted(bad)} — "
                f"resident state must keep a constant index_map so the "
                f"buffer persists across grid steps")
        elif not (used & constant_helpers):
            findings.append(
                f"PL001 {path.name}:{call.lineno}: out_specs references "
                f"no constant-index_map BlockSpec helper — resident "
                f"state discipline cannot be verified")

    seeded = any(
        isinstance(n, ast.Call) and isinstance(n.func, ast.Attribute)
        and n.func.attr == "when"
        and any(isinstance(c, ast.Call)
                and isinstance(c.func, ast.Attribute)
                and c.func.attr == "program_id"
                for a in n.args for c in ast.walk(a))
        for n in ast.walk(tree))
    if not seeded:
        findings.append(
            f"PL003 {path.name}: kernel never seeds resident outputs at "
            f"grid step 0 (no pl.when(pl.program_id(...) == 0) guard) — "
            f"out_refs start uninitialized")
    return findings


def run(paths=None, pallas_path=None) -> list:
    return check_files(paths) + check_pallas(pallas_path)
