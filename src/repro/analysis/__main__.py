"""CLI: ``python -m repro.analysis [--pass name,...] [-q]``.

Exit status 0 = every selected pass clean; 1 = findings (printed one
per line, prefixed with their invariant code).  The default selection
is the static set (contracts, lint, jaxpr) — no device execution, safe
for lint-tier CI.  ``--pass recompile`` (or ``--all``) additionally
executes a tiny ladder fill and bounds its real compile count.
"""
from __future__ import annotations

import argparse
import sys

from repro import analysis


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="one-compile invariant analyzer (see docs/"
                    "architecture.md, 'Static invariants')")
    ap.add_argument("--pass", dest="passes", default=None,
                    help="comma-separated pass subset "
                         f"(know: {', '.join(analysis.PASSES)}; "
                         f"default: {', '.join(analysis.STATIC_PASSES)})")
    ap.add_argument("--all", action="store_true",
                    help="run every pass incl. the executing recompile "
                         "guard")
    ap.add_argument("--list", action="store_true",
                    help="list passes and exit")
    ap.add_argument("-q", "--quiet", action="store_true",
                    help="suppress progress; print findings only")
    args = ap.parse_args(argv)

    if args.list:
        for p in analysis.PASSES:
            tag = "" if p in analysis.STATIC_PASSES else "  (executes)"
            print(f"{p}{tag}")
        return 0

    if args.passes:
        selected = [p.strip() for p in args.passes.split(",") if p.strip()]
        unknown = [p for p in selected if p not in analysis.PASSES]
        if unknown:
            ap.error(f"unknown pass(es) {unknown}; know {analysis.PASSES}")
    elif args.all:
        selected = list(analysis.PASSES)
    else:
        selected = list(analysis.STATIC_PASSES)

    progress = (lambda msg: None) if args.quiet else \
        (lambda msg: print(msg, file=sys.stderr))

    findings = []
    for p in selected:
        progress(f"[analysis] pass: {p}")
        got = analysis.run_pass(p, progress=progress)
        progress(f"[analysis]   {len(got)} finding(s)")
        findings += got

    for f in findings:
        print(f)
    if findings:
        print(f"[analysis] FAILED: {len(findings)} finding(s) across "
              f"{len(selected)} pass(es)", file=sys.stderr)
        return 1
    progress(f"[analysis] OK: {len(selected)} pass(es) clean")
    return 0


if __name__ == "__main__":
    sys.exit(main())
