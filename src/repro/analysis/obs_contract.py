"""OB001: the BENCH_sweep record is fully derivable from the obs trace.

The schema-6 contract (mirroring the C007 orphan-Stats discipline): no
``LADDER_PERF`` field may be hand-set in ``sim.runner`` — every field
must flow through ``obs.report.FIELD_SOURCES``, and every source must
reference something the instrumentation actually emits.  Three checks:

- the ``FIELD_SOURCES`` table and ``SCHEMA6_FIELDS`` are mutually
  closed (no orphan field, no dangling source), and each source is
  well-formed: span sums name a declared span, attr sources name an
  attribute the ``ladder_fill`` span in ``sim/runner.py`` actually sets
  (``obs.span(...)`` keywords or a later ``fill.set(...)``), derived
  sources name another field;
- ``sim/runner.py`` appends to ``LADDER_PERF`` ONLY values produced by
  ``fill_record`` — a hand-assembled dict literal is exactly the
  regression this pass exists to block;
- every name constant declared in ``obs.names`` tuples is unique (a
  duplicated string would silently merge two metrics).

Pure AST + table inspection: no jax, no execution — part of
``run_static()``.
"""
from __future__ import annotations

import ast
from pathlib import Path

from repro.obs import names as obs_names
from repro.obs import report

RUNNER_PATH = Path(__file__).resolve().parents[1] / "sim" / "runner.py"

_SOURCE_KINDS = ("attr", "sum_span_dur", "count_compiles", "derived",
                 "trace_path")


def _fill_span_attrs(runner_path=None) -> set:
    """Attribute names the runner's ladder_fill span carries: keywords
    of the ``obs.span(SPAN_LADDER_FILL, ...)`` call plus every
    ``fill.set(...)`` keyword."""
    tree = ast.parse(Path(runner_path or RUNNER_PATH).read_text())

    def _is_fill_span_call(call):
        return (isinstance(call, ast.Call)
                and isinstance(call.func, ast.Attribute)
                and call.func.attr == "span"
                and call.args
                and isinstance(call.args[0], ast.Attribute)
                and call.args[0].attr == "SPAN_LADDER_FILL")

    attrs: set = set()
    fill_names: set = set()
    for node in ast.walk(tree):
        if _is_fill_span_call(node):
            attrs |= {kw.arg for kw in node.keywords if kw.arg}
        # `fill = obs.span(SPAN_LADDER_FILL, ...)` -> track fill.set(...)
        if (isinstance(node, ast.Assign)
                and _is_fill_span_call(node.value)):
            for t in node.targets:
                if isinstance(t, ast.Name):
                    fill_names.add(t.id)
    for node in ast.walk(tree):
        if (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and node.func.attr == "set"
                and isinstance(node.func.value, ast.Name)
                and node.func.value.id in fill_names):
            attrs |= {kw.arg for kw in node.keywords if kw.arg}
    return attrs


def check_field_sources(runner_path=None) -> list:
    """Table closure + source well-formedness (the core OB001 check)."""
    findings = []
    fields = set(report.SCHEMA6_FIELDS)
    sources = set(report.FIELD_SOURCES)
    for f in sorted(fields - sources):
        findings.append(
            f"OB001 schema-6 field {f!r} has no FIELD_SOURCES entry — "
            f"it cannot be derived from the trace (orphan hand-set "
            f"field)")
    for f in sorted(sources - fields):
        findings.append(
            f"OB001 FIELD_SOURCES entry {f!r} is not a schema-6 field "
            f"(dangling source)")

    span_attrs = _fill_span_attrs(runner_path)
    for f in sorted(fields & sources):
        kind, arg = report.FIELD_SOURCES[f]
        if kind not in _SOURCE_KINDS:
            findings.append(
                f"OB001 field {f!r}: unknown source kind {kind!r} "
                f"(know {_SOURCE_KINDS})")
        elif kind == "sum_span_dur" and arg not in obs_names.SPAN_NAMES:
            findings.append(
                f"OB001 field {f!r} sums spans named {arg!r}, which is "
                f"not declared in obs.names.SPAN_NAMES — nothing emits "
                f"it")
        elif kind == "attr" and arg not in span_attrs:
            findings.append(
                f"OB001 field {f!r} reads ladder_fill attr {arg!r}, but "
                f"sim/runner.py never sets it on the fill span "
                f"(sets: {sorted(span_attrs)})")
        elif kind == "count_compiles" and arg not in span_attrs:
            findings.append(
                f"OB001 field {f!r} filters compile events by fill attr "
                f"{arg!r}, which the fill span never sets")
        elif kind == "derived" and arg not in sources:
            findings.append(
                f"OB001 field {f!r} derives from {arg!r}, which has no "
                f"FIELD_SOURCES entry")
    return findings


def check_runner_appends(runner_path=None) -> list:
    """``LADDER_PERF.append(...)`` must receive a ``fill_record`` call."""
    tree = ast.parse(Path(runner_path or RUNNER_PATH).read_text())
    findings = []
    for node in ast.walk(tree):
        if not (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and node.func.attr == "append"
                and isinstance(node.func.value, ast.Name)
                and node.func.value.id == "LADDER_PERF"):
            continue
        arg = node.args[0] if node.args else None
        ok = (isinstance(arg, ast.Call)
              and isinstance(arg.func, ast.Attribute)
              and arg.func.attr == "fill_record")
        if not ok:
            findings.append(
                f"OB001 sim/runner.py:{node.lineno}: LADDER_PERF.append "
                f"receives a hand-assembled value; records must come "
                f"from obs.report.fill_record so the artifact stays "
                f"derivable from the trace")
    return findings


def check_name_uniqueness() -> list:
    """Declared span/event/metric names must be globally unique."""
    findings = []
    all_names: list = []
    for tup in (obs_names.SPAN_NAMES, obs_names.EVENT_NAMES,
                obs_names.COUNTER_NAMES, obs_names.GAUGE_NAMES,
                obs_names.HIST_NAMES):
        all_names += list(tup)
    seen: set = set()
    for n in all_names:
        if n in seen:
            findings.append(
                f"OB001 obs.names declares {n!r} more than once — "
                f"distinct metrics would silently merge")
        seen.add(n)
    return findings


def run(runner_path=None) -> list:
    return (check_field_sources(runner_path)
            + check_runner_appends(runner_path)
            + check_name_uniqueness())
