"""OB001: the BENCH_sweep record is fully derivable from the obs trace.

The schema-6 contract (mirroring the C007 orphan-Stats discipline): no
``LADDER_PERF`` field may be hand-set in ``sim.runner`` — every field
must flow through ``obs.report.FIELD_SOURCES``, and every source must
reference something the instrumentation actually emits.  Three checks:

- the ``FIELD_SOURCES`` table and ``SCHEMA6_FIELDS`` are mutually
  closed (no orphan field, no dangling source), and each source is
  well-formed: span sums name a declared span, attr sources name an
  attribute the ``ladder_fill`` span in ``sim/runner.py`` actually sets
  (``obs.span(...)`` keywords or a later ``fill.set(...)``), derived
  sources name another field;
- ``sim/runner.py`` appends to ``LADDER_PERF`` ONLY values produced by
  ``fill_record`` — a hand-assembled dict literal is exactly the
  regression this pass exists to block;
- every name constant declared in ``obs.names`` tuples is unique (a
  duplicated string would silently merge two metrics).

The serving-side BENCH_serve record (``report.SERVE_FIELDS``, produced
by ``serve/load.py``) gets the same treatment: table closure against
``SERVE_FIELD_SOURCES``, attr sources must be set on the
``serve.load_run`` span (open keywords or a later ``.set(...)``), count
sums must name declared counters, duration quantiles must name declared
spans, and ``SERVE_PERF.append`` may only receive ``serve_record``
output.

Pure AST + table inspection: no jax, no execution — part of
``run_static()``.
"""
from __future__ import annotations

import ast
from pathlib import Path

from repro.obs import names as obs_names
from repro.obs import report

RUNNER_PATH = Path(__file__).resolve().parents[1] / "sim" / "runner.py"
LOAD_PATH = Path(__file__).resolve().parents[1] / "serve" / "load.py"

_SOURCE_KINDS = ("attr", "sum_span_dur", "count_compiles", "derived",
                 "trace_path")
_SERVE_SOURCE_KINDS = ("attr", "sum_counts", "dur_quantile", "span_dur",
                       "derived", "trace_path")


def _span_attrs(path, span_const: str) -> set:
    """Attribute names a span opened as ``obs.span(<span_const>, ...)``
    in `path` carries: the open call's keywords plus every later
    ``<handle>.set(...)`` keyword (the handle being whatever name the
    span call — or a `with ... as` clause — bound)."""
    tree = ast.parse(Path(path).read_text())

    def _is_span_call(call):
        return (isinstance(call, ast.Call)
                and isinstance(call.func, ast.Attribute)
                and call.func.attr == "span"
                and call.args
                and isinstance(call.args[0], ast.Attribute)
                and call.args[0].attr == span_const)

    attrs: set = set()
    handles: set = set()
    for node in ast.walk(tree):
        if _is_span_call(node):
            attrs |= {kw.arg for kw in node.keywords if kw.arg}
        # `fill = obs.span(X, ...)` -> track fill.set(...)
        if (isinstance(node, ast.Assign)
                and _is_span_call(node.value)):
            for t in node.targets:
                if isinstance(t, ast.Name):
                    handles.add(t.id)
        # `with obs.span(X, ...) as run:` -> track run.set(...)
        if isinstance(node, (ast.With, ast.AsyncWith)):
            for item in node.items:
                if (_is_span_call(item.context_expr)
                        and isinstance(item.optional_vars, ast.Name)):
                    handles.add(item.optional_vars.id)
    for node in ast.walk(tree):
        if (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and node.func.attr == "set"
                and isinstance(node.func.value, ast.Name)
                and node.func.value.id in handles):
            attrs |= {kw.arg for kw in node.keywords if kw.arg}
    return attrs


def _fill_span_attrs(runner_path=None) -> set:
    """Attribute names the runner's ladder_fill span carries: keywords
    of the ``obs.span(SPAN_LADDER_FILL, ...)`` call plus every
    ``fill.set(...)`` keyword."""
    return _span_attrs(runner_path or RUNNER_PATH, "SPAN_LADDER_FILL")


def check_field_sources(runner_path=None) -> list:
    """Table closure + source well-formedness (the core OB001 check)."""
    findings = []
    fields = set(report.SCHEMA6_FIELDS)
    sources = set(report.FIELD_SOURCES)
    for f in sorted(fields - sources):
        findings.append(
            f"OB001 schema-6 field {f!r} has no FIELD_SOURCES entry — "
            f"it cannot be derived from the trace (orphan hand-set "
            f"field)")
    for f in sorted(sources - fields):
        findings.append(
            f"OB001 FIELD_SOURCES entry {f!r} is not a schema-6 field "
            f"(dangling source)")

    span_attrs = _fill_span_attrs(runner_path)
    for f in sorted(fields & sources):
        kind, arg = report.FIELD_SOURCES[f]
        if kind not in _SOURCE_KINDS:
            findings.append(
                f"OB001 field {f!r}: unknown source kind {kind!r} "
                f"(know {_SOURCE_KINDS})")
        elif kind == "sum_span_dur" and arg not in obs_names.SPAN_NAMES:
            findings.append(
                f"OB001 field {f!r} sums spans named {arg!r}, which is "
                f"not declared in obs.names.SPAN_NAMES — nothing emits "
                f"it")
        elif kind == "attr" and arg not in span_attrs:
            findings.append(
                f"OB001 field {f!r} reads ladder_fill attr {arg!r}, but "
                f"sim/runner.py never sets it on the fill span "
                f"(sets: {sorted(span_attrs)})")
        elif kind == "count_compiles" and arg not in span_attrs:
            findings.append(
                f"OB001 field {f!r} filters compile events by fill attr "
                f"{arg!r}, which the fill span never sets")
        elif kind == "derived" and arg not in sources:
            findings.append(
                f"OB001 field {f!r} derives from {arg!r}, which has no "
                f"FIELD_SOURCES entry")
    return findings


def check_runner_appends(runner_path=None) -> list:
    """``LADDER_PERF.append(...)`` must receive a ``fill_record`` call."""
    tree = ast.parse(Path(runner_path or RUNNER_PATH).read_text())
    findings = []
    for node in ast.walk(tree):
        if not (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and node.func.attr == "append"
                and isinstance(node.func.value, ast.Name)
                and node.func.value.id == "LADDER_PERF"):
            continue
        arg = node.args[0] if node.args else None
        ok = (isinstance(arg, ast.Call)
              and isinstance(arg.func, ast.Attribute)
              and arg.func.attr == "fill_record")
        if not ok:
            findings.append(
                f"OB001 sim/runner.py:{node.lineno}: LADDER_PERF.append "
                f"receives a hand-assembled value; records must come "
                f"from obs.report.fill_record so the artifact stays "
                f"derivable from the trace")
    return findings


def check_serve_field_sources(load_path=None) -> list:
    """SERVE_FIELDS ↔ SERVE_FIELD_SOURCES closure + well-formedness."""
    findings = []
    fields = set(report.SERVE_FIELDS)
    sources = set(report.SERVE_FIELD_SOURCES)
    for f in sorted(fields - sources):
        findings.append(
            f"OB001 serve field {f!r} has no SERVE_FIELD_SOURCES entry — "
            f"it cannot be derived from the trace (orphan hand-set "
            f"field)")
    for f in sorted(sources - fields):
        findings.append(
            f"OB001 SERVE_FIELD_SOURCES entry {f!r} is not a serve "
            f"field (dangling source)")

    span_attrs = _span_attrs(load_path or LOAD_PATH, "SPAN_SERVE_RUN")
    for f in sorted(fields & sources):
        kind, arg = report.SERVE_FIELD_SOURCES[f]
        if kind not in _SERVE_SOURCE_KINDS:
            findings.append(
                f"OB001 serve field {f!r}: unknown source kind {kind!r} "
                f"(know {_SERVE_SOURCE_KINDS})")
        elif kind == "attr" and arg not in span_attrs:
            findings.append(
                f"OB001 serve field {f!r} reads serve.load_run attr "
                f"{arg!r}, but serve/load.py never sets it on the run "
                f"span (sets: {sorted(span_attrs)})")
        elif kind == "sum_counts" and arg not in obs_names.COUNTER_NAMES:
            findings.append(
                f"OB001 serve field {f!r} sums counts named {arg!r}, "
                f"which is not declared in obs.names.COUNTER_NAMES — "
                f"nothing emits it")
        elif kind == "dur_quantile" and arg[0] not in obs_names.SPAN_NAMES:
            findings.append(
                f"OB001 serve field {f!r} takes quantiles of spans named "
                f"{arg[0]!r}, which is not declared in "
                f"obs.names.SPAN_NAMES — nothing emits it")
        elif kind == "derived":
            for a in (arg if isinstance(arg, tuple) else (arg,)):
                if a not in sources:
                    findings.append(
                        f"OB001 serve field {f!r} derives from {a!r}, "
                        f"which has no SERVE_FIELD_SOURCES entry")
    return findings


def check_load_appends(load_path=None) -> list:
    """``SERVE_PERF.append(...)`` must receive a ``serve_record`` call."""
    tree = ast.parse(Path(load_path or LOAD_PATH).read_text())
    findings = []
    for node in ast.walk(tree):
        if not (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and node.func.attr == "append"
                and isinstance(node.func.value, ast.Name)
                and node.func.value.id == "SERVE_PERF"):
            continue
        arg = node.args[0] if node.args else None
        ok = ((isinstance(arg, ast.Call)
               and isinstance(arg.func, ast.Attribute)
               and arg.func.attr == "serve_record")
              or (isinstance(arg, ast.Name)
                  and _assigned_from_serve_record(tree, arg.id)))
        if not ok:
            findings.append(
                f"OB001 serve/load.py:{node.lineno}: SERVE_PERF.append "
                f"receives a hand-assembled value; records must come "
                f"from obs.report.serve_record so BENCH_serve stays "
                f"derivable from the trace")
    return findings


def _assigned_from_serve_record(tree, name: str) -> bool:
    """True when every ``name = ...`` assignment is a serve_record call
    (the `rec = serve_record(...); SERVE_PERF.append(rec)` idiom)."""
    assigns = [n for n in ast.walk(tree)
               if isinstance(n, ast.Assign)
               and any(isinstance(t, ast.Name) and t.id == name
                       for t in n.targets)]
    return bool(assigns) and all(
        isinstance(a.value, ast.Call)
        and isinstance(a.value.func, ast.Attribute)
        and a.value.func.attr == "serve_record"
        for a in assigns)


def check_name_uniqueness() -> list:
    """Declared span/event/metric names must be globally unique."""
    findings = []
    all_names: list = []
    for tup in (obs_names.SPAN_NAMES, obs_names.EVENT_NAMES,
                obs_names.COUNTER_NAMES, obs_names.GAUGE_NAMES,
                obs_names.HIST_NAMES):
        all_names += list(tup)
    seen: set = set()
    for n in all_names:
        if n in seen:
            findings.append(
                f"OB001 obs.names declares {n!r} more than once — "
                f"distinct metrics would silently merge")
        seen.add(n)
    return findings


def run(runner_path=None) -> list:
    return (check_field_sources(runner_path)
            + check_runner_appends(runner_path)
            + check_serve_field_sources()
            + check_load_appends()
            + check_name_uniqueness())
