"""Recompile guard: count named XLA compiles under ``jax_log_compiles``.

The one-compile invariant says a whole dyn-gated ladder family fills
through ONE compiled dispatch per (shape, backend).  ``jax.monitoring``
events (``/jax/core/compile/backend_compile_duration`` etc.) carry no
function names, so they cannot distinguish the ladder dispatch from the
tiny eager-op jits (``dynamic_slice``, ``convert_element_type``, ...)
that fire around it.  Instead we flip ``jax_log_compiles`` on, which
makes jax's internal loggers emit one ``"Compiling <name> ..."`` record
per jit-cache miss — *before* the persistent-cache lookup, so a
lowering is counted even when the XLA binary comes out of
``.jax_cache``.  That is exactly the event whose count the invariant
bounds.

This module deliberately imports nothing from ``repro`` so that
``sim.runner`` can use it without an import cycle.
"""
from __future__ import annotations

import logging
from contextlib import contextmanager
from dataclasses import dataclass, field

# every logger jax routes "Compiling <name>" records through, across the
# jit / shard_map / pmap paths (version-dependent; harmless if absent)
_JAX_COMPILE_LOGGERS = (
    "jax._src.interpreters.pxla",
    "jax._src.pjit",
    "jax._src.dispatch",
)

_PREFIX = "Compiling "

# the name the sharded ladder dispatch compiles under — the inner
# function built by ``mmu.make_systems_runner`` and wrapped by
# ``parallel.shard_wrap``
DISPATCH_NAME = "run_systems"


@dataclass
class CompileLog:
    """Names of functions compiled while a ``count_compiles`` block ran."""

    names: list = field(default_factory=list)

    def count(self, name: str | None = None) -> int:
        """Total compiles, or compiles of one function name."""
        if name is None:
            return len(self.names)
        return sum(1 for n in self.names if n == name)

    def by_name(self) -> dict:
        out: dict[str, int] = {}
        for n in self.names:
            out[n] = out.get(n, 0) + 1
        return out


class _Capture(logging.Handler):
    def __init__(self, log: CompileLog, on_compile=None):
        super().__init__(level=logging.DEBUG)
        self._log = log
        self._on_compile = on_compile

    def emit(self, record: logging.LogRecord) -> None:
        msg = record.getMessage()
        if msg.startswith(_PREFIX):
            # "Compiling <name> with global shapes and types ..." /
            # "Compiling <name> (<id>) for with global shapes ..."
            name = msg[len(_PREFIX):].split()[0]
            self._log.names.append(name)
            if self._on_compile is not None:
                try:
                    self._on_compile(name)
                except Exception:  # telemetry must never kill a compile
                    pass


@contextmanager
def count_compiles(on_compile=None):
    """Context manager yielding a :class:`CompileLog` of jit-cache misses.

    Temporarily enables ``jax_log_compiles`` and attaches a capturing
    handler to jax's compile loggers with propagation off (so user
    terminals are not spammed with WARNING records); both are restored
    on exit.  Nesting is safe — each level sees every compile inside it.
    ``on_compile(name)``, if given, fires per captured compile — the hook
    ``sim.runner`` uses to land every jit-cache miss in the obs trace.
    """
    import jax  # deferred: keep module importable without initializing jax

    log = CompileLog()
    handler = _Capture(log, on_compile)
    prev_flag = jax.config.jax_log_compiles
    loggers = [logging.getLogger(n) for n in _JAX_COMPILE_LOGGERS]
    prev = [(lg.level, lg.propagate) for lg in loggers]
    jax.config.update("jax_log_compiles", True)
    for lg in loggers:
        lg.addHandler(handler)
        if lg.level > logging.WARNING or lg.level == logging.NOTSET:
            lg.setLevel(logging.WARNING)
        lg.propagate = False
    try:
        yield log
    finally:
        for lg, (lvl, prop) in zip(loggers, prev):
            lg.removeHandler(handler)
            lg.setLevel(lvl)
            lg.propagate = prop
        jax.config.update("jax_log_compiles", prev_flag)


def check_ladder_dispatch(members=None, workloads=("rnd", "bc"), n: int = 256,
                          backend: str = "scan", expected: int = 1):
    """Execute a tiny ladder fill and bound its dispatch compile count.

    Builds a ``make_systems_runner`` dispatch for ``members`` (default:
    the first two members of the first discovered family), feeds it two
    same-shape workload chunks, and returns findings if the number of
    ``run_systems`` compiles differs from ``expected``.  This actually
    runs the simulator, so it lives behind ``--pass recompile`` in the
    CLI rather than in the default static sweep.
    """
    import jax
    import numpy as np
    import jax.numpy as jnp

    from repro.core import mmu
    from repro.sim import parallel, systems, trace_gen

    if members is None:
        fam = sorted(systems.discover_ladders().items(),
                     key=lambda kv: -len(kv[1]))[0][1]
        members = list(fam)[:2]
    base = systems.ladder_base_config(members=members)
    dyns = systems.ladder_dyn(members)
    plan = parallel.plan_mesh(len(members), len(workloads))
    run_fn = mmu.make_systems_runner(base, plan, None, backend, None, 1)

    def chunk(seed):
        gens = [trace_gen.generate(w, n=n, seed=seed) for w in workloads]
        tr = {k: jnp.asarray(np.stack([g["trace"][k] for g in gens], axis=1))
              for k in gens[0]["trace"]}
        tr["ipa"] = jnp.asarray(np.broadcast_to(
            np.asarray([g["spec"].ipa for g in gens], np.float32),
            (n, len(gens))))
        return tr

    with count_compiles() as log:
        for seed in (0, 1):  # two same-shape chunks must share one compile
            per, extras = run_fn(dyns, chunk(seed))
            jax.block_until_ready((per, extras))
    got = log.count(DISPATCH_NAME)

    findings = []
    if got != expected:
        findings.append(
            f"RC001 recompile guard: {len(members)}-member ladder "
            f"({backend} backend) compiled '{DISPATCH_NAME}' {got}x over "
            f"two same-shape chunks; the one-compile invariant allows "
            f"exactly {expected} per (shape, backend).  Full compile "
            f"log: {log.by_name()}")
    return findings
