"""Stage-contract checker: introspection + AST over ``core/stages/*``.

Checked invariants (each finding is prefixed with its code):

- C001 every registered stage exposes ``lookup(self, cfg, st, req,
  need)`` and ``fill(self, cfg, st, req, out)`` with exactly those
  parameters, a non-placeholder ``name`` matching its registry key, and
  a bool ``past_l2`` declaration.
- C002 every registry system composition validates (flags agree with
  the stage list) and ends in a walker stage.
- C003 the dyn-gating tables are closed: every ``DYN_GATED_STAGES``
  entry names a real stage / SimConfig field / Dyn gate, and every
  ``DYN_FIELDS`` entry is a SimConfig field set by ``dyn_of``.
- C004 sized-1-when-off: state for a gated stage is allocated with the
  ``<expr> if cfg.<flag> else 1`` (or ``max(<expr>, 1)``) convention in
  ``make_state`` — an off lane carries a 1-entry structure, not a full
  allocation, which is what keeps the ladder base state shape-shared.
- C005/C006 every ``Stats`` field follows the ``n_*/sum_*/hist_*``
  naming convention and is folded accumulatively (reads ``s0.<field>``)
  in exactly one keyword of ``fold.accum_stats``'s ``Stats(...)``
  return, with at most one *stage* source feeding it (single-writer).
- C007 every ``Stats`` field is surfaced: read as ``stats.<field>``
  somewhere in ``core/metrics.py`` or ``core/timing.py`` (an orphan
  field is dead telemetry — an error, not a warning).
- C008 stage code writes only into its OWN result slot:
  ``out[...].info[...] = ...`` targets must be ``out[self.name]``.

Every check takes explicit inputs (objects or file paths) so the test
fixtures can aim it at deliberately broken stages; ``run()`` wires the
real repo defaults.
"""
from __future__ import annotations

import ast
import inspect
import re
from pathlib import Path

STAGES_DIR = Path(__file__).resolve().parents[1] / "core" / "stages"
METRIC_PATHS = (
    Path(__file__).resolve().parents[1] / "core" / "metrics.py",
    Path(__file__).resolve().parents[1] / "core" / "timing.py",
)

LOOKUP_PARAMS = ("self", "cfg", "st", "req", "need")
FILL_PARAMS = ("self", "cfg", "st", "req", "out")

STATS_FIELD_RE = re.compile(r"^(n_|sum_|hist_)")

# state fields allocated per gated feature: cfg gate flag -> MMUState
# kwargs that must follow the sized-1-when-off convention.  The L3 TLB
# gates on a size (l3tlb_sets > 0), hence the max(x, 1) variant.
STATE_GATES = {
    "pom": ("pom",),
    "utopia": ("restseg4", "restseg2"),
    "revelator": ("rev",),
    "virt": ("ntlb", "pch"),
    "collect": ("feats",),
}
STATE_MAX_GATES = ("l3tlb",)  # sized via max(cfg.*_sets, 1)
# size-gated allocations nested inside another kwarg's constructor call:
# state kwarg -> cfg size fields that must each appear as max(cfg.<f>, 1).
# The die-stacked DRAM cache rides the Hier constructor, so its
# sized-1-when-off guard lives inside the hier= expression.
STATE_NESTED_MAX_GATES = {"hier": ("dram_cache_sets",)}


# --------------------------------------------------------------- C001


def check_stage_objects(stages=None) -> list:
    from repro.core import stages as stage_mod

    stages = stage_mod.STAGES if stages is None else stages
    findings = []
    for key, stg in stages.items():
        cls = type(stg).__name__
        if getattr(stg, "name", "?") in ("?", "", None):
            findings.append(
                f"C001 stage {cls}: placeholder/missing 'name' attribute")
        elif stg.name != key:
            findings.append(
                f"C001 stage {cls}: name {stg.name!r} != registry key "
                f"{key!r}")
        if not isinstance(getattr(stg, "past_l2", None), bool):
            findings.append(
                f"C001 stage {cls}: 'past_l2' must be declared as a bool "
                f"(got {getattr(stg, 'past_l2', None)!r})")
        for meth, want in (("lookup", LOOKUP_PARAMS), ("fill", FILL_PARAMS)):
            fn = getattr(type(stg), meth, None)
            if fn is None:
                findings.append(f"C001 stage {cls}: missing {meth}()")
                continue
            got = tuple(inspect.signature(fn).parameters)
            if got != want:
                findings.append(
                    f"C001 stage {cls}: {meth}{got} violates the stage "
                    f"contract {meth}{want}")
    return findings


# --------------------------------------------------------------- C002


def check_registry(registry=None) -> list:
    from repro.core import stages as stage_mod
    from repro.sim import systems

    registry = systems.REGISTRY if registry is None else registry
    findings = []
    for name, sys_ in registry.items():
        unknown = [s for s in sys_.stages if s not in stage_mod.STAGES]
        if unknown:
            findings.append(
                f"C002 system {name!r}: unknown stages {unknown}")
            continue
        if sys_.stages[-1] not in stage_mod.WALK_STAGES:
            findings.append(
                f"C002 system {name!r}: composition must end in a walker "
                f"stage {stage_mod.WALK_STAGES}, ends in "
                f"{sys_.stages[-1]!r}")
        try:
            stage_mod.validate_stages(sys_.config(), sys_.stages)
        except ValueError as e:
            findings.append(f"C002 system {name!r}: {e}")
    return findings


# --------------------------------------------------------------- C003


def check_dyn_tables() -> list:
    import dataclasses

    from repro.core import stages as stage_mod
    from repro.core.stages.base import DYN_FIELDS, Dyn, SimConfig
    from repro.sim import systems

    cfg_fields = {f.name for f in dataclasses.fields(SimConfig)}
    findings = []
    for stage, (cfg_field, gate) in systems.DYN_GATED_STAGES.items():
        if stage not in stage_mod.STAGES:
            findings.append(
                f"C003 DYN_GATED_STAGES[{stage!r}]: not a registered stage")
        if cfg_field not in cfg_fields:
            findings.append(
                f"C003 DYN_GATED_STAGES[{stage!r}]: {cfg_field!r} is not "
                f"a SimConfig field")
        if gate not in Dyn._fields:
            findings.append(
                f"C003 DYN_GATED_STAGES[{stage!r}]: gate {gate!r} is not "
                f"a Dyn field")
    for f in DYN_FIELDS:
        if f not in cfg_fields:
            findings.append(f"C003 DYN_FIELDS entry {f!r}: not a "
                            f"SimConfig field")
    return findings


# --------------------------------------------------------------- C004


def _gated_ok(node: ast.expr, flag: str) -> bool:
    """Does ``node`` contain ``<x> if cfg.<flag> else 1``?"""
    for sub in ast.walk(node):
        if (isinstance(sub, ast.IfExp)
                and isinstance(sub.orelse, ast.Constant)
                and sub.orelse.value == 1):
            for t in ast.walk(sub.test):
                if (isinstance(t, ast.Attribute) and t.attr == flag
                        and isinstance(t.value, ast.Name)
                        and t.value.id == "cfg"):
                    return True
    return False


def _max1_ok(node: ast.expr) -> bool:
    """Does ``node`` contain ``max(<x>, 1)``?"""
    for sub in ast.walk(node):
        if (isinstance(sub, ast.Call) and isinstance(sub.func, ast.Name)
                and sub.func.id == "max"
                and any(isinstance(a, ast.Constant) and a.value == 1
                        for a in sub.args)):
            return True
    return False


def _max1_of(node: ast.expr, field: str) -> bool:
    """Does ``node`` contain ``max(<x involving cfg.field>, 1)``?"""
    for sub in ast.walk(node):
        if (isinstance(sub, ast.Call) and isinstance(sub.func, ast.Name)
                and sub.func.id == "max"
                and any(isinstance(a, ast.Constant) and a.value == 1
                        for a in sub.args)
                and any(isinstance(t, ast.Attribute) and t.attr == field
                        and isinstance(t.value, ast.Name)
                        and t.value.id == "cfg"
                        for a in sub.args for t in ast.walk(a))):
            return True
    return False


def check_make_state(path=None, state_gates=None, max_gates=None,
                     nested_max_gates=None) -> list:
    path = Path(path) if path else STAGES_DIR / "base.py"
    state_gates = STATE_GATES if state_gates is None else state_gates
    max_gates = STATE_MAX_GATES if max_gates is None else max_gates
    nested_max_gates = (STATE_NESTED_MAX_GATES if nested_max_gates is None
                        else nested_max_gates)
    tree = ast.parse(path.read_text())
    fn = next((n for n in ast.walk(tree)
               if isinstance(n, ast.FunctionDef) and n.name == "make_state"),
              None)
    if fn is None:
        return [f"C004 {path.name}: no make_state() found"]
    call = next((n for n in ast.walk(fn)
                 if isinstance(n, ast.Call) and isinstance(n.func, ast.Name)
                 and n.func.id == "MMUState"), None)
    if call is None:
        return [f"C004 {path.name}: make_state() does not build MMUState"]
    kwargs = {kw.arg: kw.value for kw in call.keywords if kw.arg}

    findings = []
    for flag, state_fields in state_gates.items():
        for sf in state_fields:
            if sf not in kwargs:
                findings.append(
                    f"C004 make_state: expected state field {sf!r} "
                    f"(gated by cfg.{flag}) is not allocated")
            elif not _gated_ok(kwargs[sf], flag):
                findings.append(
                    f"C004 make_state: state field {sf!r} must follow the "
                    f"sized-1-when-off convention "
                    f"('<sets> if cfg.{flag} else 1') so off lanes carry "
                    f"a 1-entry structure")
    for sf in max_gates:
        if sf not in kwargs:
            findings.append(
                f"C004 make_state: expected state field {sf!r} is not "
                f"allocated")
        elif not _max1_ok(kwargs[sf]):
            findings.append(
                f"C004 make_state: state field {sf!r} gates on a size and "
                f"must be allocated via max(<sets>, 1)")
    for sf, cfg_fields in nested_max_gates.items():
        for cf in cfg_fields:
            if sf not in kwargs:
                findings.append(
                    f"C004 make_state: expected state field {sf!r} is not "
                    f"allocated")
            elif not _max1_of(kwargs[sf], cf):
                findings.append(
                    f"C004 make_state: state field {sf!r} must size its "
                    f"cfg.{cf} region via max(cfg.{cf}, 1) so off lanes "
                    f"carry a 1-entry structure")
    return findings


# ---------------------------------------------------------- C005/C006


def _stage_sources(node: ast.expr, env: dict) -> set:
    """Stage names feeding an accumulation expression.

    ``out["x"]`` / ``_hit32(out, "x")`` attribute to stage x;
    ``walk_res`` to the walker; locals resolve through ``env`` (the
    name -> sources map built while walking accum_stats's body).
    """
    src: set = set()
    for sub in ast.walk(node):
        if (isinstance(sub, ast.Subscript) and isinstance(sub.value, ast.Name)
                and sub.value.id == "out"
                and isinstance(sub.slice, ast.Constant)):
            src.add(str(sub.slice.value))
        elif isinstance(sub, ast.Name):
            if sub.id == "walk_res":
                src.add("<walker>")
            elif sub.id in env:
                src |= env[sub.id]
        elif (isinstance(sub, ast.Call) and isinstance(sub.func, ast.Name)
                and sub.func.id == "_hit32" and len(sub.args) >= 2
                and isinstance(sub.args[1], ast.Constant)):
            src.add(str(sub.args[1].value))
    return src


def check_stats_fold(stats_fields=None, fold_path=None) -> list:
    """C005: every Stats field folded accumulatively in accum_stats;
    C006: at most one stage source per field (single-writer)."""
    if stats_fields is None:
        from repro.core.stages.base import Stats

        stats_fields = Stats._fields
    fold_path = Path(fold_path) if fold_path else STAGES_DIR / "fold.py"
    tree = ast.parse(fold_path.read_text())
    fn = next((n for n in ast.walk(tree)
               if isinstance(n, ast.FunctionDef)
               and n.name == "accum_stats"), None)
    if fn is None:
        return [f"C005 {fold_path.name}: no accum_stats() found"]

    findings = []
    for f in stats_fields:
        if not STATS_FIELD_RE.match(f):
            findings.append(
                f"C005 Stats.{f}: violates the n_*/sum_*/hist_* naming "
                f"convention")

    # taint map: local name -> stage sources, in statement order
    env: dict = {}
    ret_call = None
    for stmt in fn.body:
        if isinstance(stmt, ast.Return) and isinstance(stmt.value, ast.Call):
            ret_call = stmt.value
        for sub in ast.walk(stmt):
            if isinstance(sub, ast.Assign):
                srcs = _stage_sources(sub.value, env)
                for tgt in sub.targets:
                    for t in ast.walk(tgt):
                        if isinstance(t, ast.Name):
                            env[t.id] = env.get(t.id, set()) | srcs
    if (ret_call is None or not isinstance(ret_call.func, ast.Name)
            or ret_call.func.id != "Stats"):
        return findings + [
            f"C005 {fold_path.name}: accum_stats must return Stats(...)"]

    folded = {kw.arg: kw.value for kw in ret_call.keywords if kw.arg}
    for f in stats_fields:
        if f not in folded:
            findings.append(
                f"C005 Stats.{f}: not folded — accum_stats's Stats(...) "
                f"return has no {f}= keyword (orphan field: the "
                f"accumulator silently drops it)")
            continue
        reads_s0 = any(
            isinstance(sub, ast.Attribute) and sub.attr == f
            and isinstance(sub.value, ast.Name) and sub.value.id == "s0"
            for sub in ast.walk(folded[f]))
        if not reads_s0:
            findings.append(
                f"C005 Stats.{f}: fold is not accumulative — the "
                f"expression never reads s0.{f}, so per-step values "
                f"overwrite instead of accumulate")
        stage_srcs = {s for s in _stage_sources(folded[f], env)
                      if s not in ("_walk",)}
        if len(stage_srcs) > 1:
            findings.append(
                f"C006 Stats.{f}: written by {len(stage_srcs)} stages "
                f"({sorted(stage_srcs)}); every Stats field must have "
                f"exactly one writer")
    for extra in sorted(set(folded) - set(stats_fields)):
        findings.append(
            f"C005 accum_stats folds unknown field {extra!r} (not a "
            f"Stats field)")
    return findings


# --------------------------------------------------------------- C007


def check_stats_surfaced(stats_fields=None, metric_paths=None) -> list:
    if stats_fields is None:
        from repro.core.stages.base import Stats

        stats_fields = Stats._fields
    metric_paths = [Path(p) for p in (metric_paths or METRIC_PATHS)]

    read: set = set()
    for p in metric_paths:
        for sub in ast.walk(ast.parse(p.read_text())):
            if isinstance(sub, ast.Attribute):
                read.add(sub.attr)
    return [
        f"C007 Stats.{f}: orphan — accumulated every step but never read "
        f"by {'/'.join(p.name for p in metric_paths)}; surface it as a "
        f"metric or delete it"
        for f in stats_fields if f not in read
    ]


# --------------------------------------------------------------- C008


def check_stage_info_writes(stage_dir=None) -> list:
    stage_dir = Path(stage_dir) if stage_dir else STAGES_DIR
    findings = []
    for path in sorted(stage_dir.glob("*.py")):
        if path.name in ("base.py", "fold.py", "__init__.py"):
            continue
        tree = ast.parse(path.read_text())
        for node in ast.walk(tree):
            if not isinstance(node, ast.Assign):
                continue
            for tgt in node.targets:
                # match out[<X>].info[...] = ...
                if not (isinstance(tgt, ast.Subscript)
                        and isinstance(tgt.value, ast.Attribute)
                        and tgt.value.attr == "info"
                        and isinstance(tgt.value.value, ast.Subscript)
                        and isinstance(tgt.value.value.value, ast.Name)
                        and tgt.value.value.value.id == "out"):
                    continue
                key = tgt.value.value.slice
                own = (isinstance(key, ast.Attribute)
                       and key.attr == "name"
                       and isinstance(key.value, ast.Name)
                       and key.value.id == "self")
                if not own:
                    findings.append(
                        f"C008 {path.name}:{node.lineno}: stage writes "
                        f"into a foreign result slot "
                        f"(out[{ast.unparse(key)}].info); stages may only "
                        f"publish into out[self.name].info")
    return findings


# ---------------------------------------------------------------- run


def run() -> list:
    """All contract checks against the real repo; returns findings."""
    findings = []
    findings += check_stage_objects()
    findings += check_registry()
    findings += check_dyn_tables()
    findings += check_make_state()
    findings += check_stats_fold()
    findings += check_stats_surfaced()
    findings += check_stage_info_writes()
    return findings
