"""Victima Translation Cache (VTC) — the paper's mechanism, TPU-adapted.

Three tiers mirror the paper's hierarchy (DESIGN.md §2.2):

  1. TC  — small set-associative translation cache (the "L2 TLB"):
     (req, block) → phys page, SMEM/VMEM-resident at kernel launch.
  2. **Translation cluster pages** — Victima's key idea transplanted:
     *unused pages of the KV pool itself* are retagged to hold clusters of
     CLUSTER=8 leaf translations.  A cluster hit costs ONE gather instead
     of the 2-hop radix walk (paper: one L2 access instead of a PTW).
  3. Radix walk (``block_table.walk``) — the slow path; updates the
     per-leaf (freq, cost) counters.

Insertion is gated by the paper's exact PTW-CP comparator box
(1,1)–(12,7) on those counters, and the pool eviction policy is
TLB-aware SRRIP: cluster pages are protected while TC pressure is high.
All state is integer arrays; every op is jit/scan-safe.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.assoc import RRIP_MAX
from repro.paged import block_table as btab

CLUSTER = 8  # translations per cluster line (paper: 8 PTEs / 64B block)


class VTC(NamedTuple):
    # tier 1: set-associative TC
    tc_tags: jax.Array      # int32 [S, W]  key = (req << 20) | block
    tc_phys: jax.Array      # int32 [S, W]
    tc_valid: jax.Array     # bool  [S, W]
    tc_stamp: jax.Array     # int32 [S, W]
    # tier 2: cluster pages carved from the KV pool
    cl_tags: jax.Array      # int32 [n_cl]  key = (req<<20 | block) >> 3
    cl_phys: jax.Array      # int32 [n_cl, CLUSTER]
    cl_valid: jax.Array     # bool  [n_cl]
    cl_rrpv: jax.Array      # int32 [n_cl]
    # stats
    n_hit_tc: jax.Array
    n_hit_cluster: jax.Array
    n_walk: jax.Array
    now: jax.Array


def _pow2(v: int) -> bool:
    return v >= 1 and (v & (v - 1)) == 0


def make(tc_sets: int = 64, tc_ways: int = 4, n_clusters: int = 256) -> VTC:
    # ``translate`` indexes sets with ``key & (S - 1)`` and hashes
    # clusters via ``(n_cl - 1).bit_length()`` — both silently mis-index
    # (aliasing distinct keys, skipping slots) unless the counts are
    # powers of two, so reject anything else up front.  n_clusters=1
    # (2^0) is the valid no-cluster ablation: the hash degenerates to
    # slot 0 (see ``translate``).
    if not _pow2(tc_sets):
        raise ValueError(
            f"tc_sets must be a power of two (set indexing is "
            f"`key & (tc_sets - 1)`), got {tc_sets}")
    if not _pow2(n_clusters):
        raise ValueError(
            f"n_clusters must be a power of two (the cluster hash takes "
            f"the top `log2(n_clusters)` product bits), got {n_clusters}")
    if tc_ways < 1:
        raise ValueError(f"tc_ways must be >= 1, got {tc_ways}")
    z = jnp.zeros((tc_sets, tc_ways), jnp.int32)
    return VTC(
        tc_tags=z, tc_phys=z,
        tc_valid=jnp.zeros((tc_sets, tc_ways), jnp.bool_),
        tc_stamp=z,
        cl_tags=jnp.zeros((n_clusters,), jnp.int32),
        cl_phys=jnp.full((n_clusters, CLUSTER), -1, jnp.int32),
        cl_valid=jnp.zeros((n_clusters,), jnp.bool_),
        cl_rrpv=jnp.full((n_clusters,), RRIP_MAX, jnp.int32),
        n_hit_tc=jnp.int32(0), n_hit_cluster=jnp.int32(0),
        n_walk=jnp.int32(0), now=jnp.int32(0),
    )


def _key(req, block):
    return (req << 20) | block


def translate(vtc: VTC, bt: btab.BlockTables, req, block, pressure,
              gate: tuple = (1, 1)):
    """Full Victima translation flow for one (req, block).

    Returns (vtc, bt, phys_page, source) with source 0=TC, 1=cluster,
    2=walk.  State updates mirror the paper §5.2/§5.3:
      miss in TC → probe cluster pages ∥ start walk; on walk completion
      the PTW-CP box decides whether to install the 8-translation cluster;
      TC refill always happens; TC eviction triggers a background install.

    ``gate = (freq_min, cost_min)`` are the PTW-CP cluster-install
    thresholds (static Python ints, part of the compiled graph).  The
    default (1, 1) is the serving refit of the paper's box (see the
    comment at the install site); ``(0, 0)`` is install-always.  The
    serving load harness tunes these from the simulator's PTW-CP sweep
    (``serve.load.tune_gate``).
    """
    now = vtc.now + 1
    vtc = vtc._replace(now=now)
    key = _key(req, block)
    S = vtc.tc_tags.shape[0]
    s = key & (S - 1)
    row_hit = vtc.tc_valid[s] & (vtc.tc_tags[s] == key)
    tc_hit = jnp.any(row_hit)
    w_hit = jnp.argmax(row_hit)
    vtc = vtc._replace(tc_stamp=vtc.tc_stamp.at[s, w_hit].set(
        jnp.where(tc_hit, now, vtc.tc_stamp[s, w_hit])))

    # tier 2: cluster probe (direct-mapped on the cluster key)
    ckey = key >> 3
    n_cl = vtc.cl_tags.shape[0]
    # Knuth multiplicative hash, TAKING THE HIGH BITS: req lives in the
    # key's high bits, and low product bits only see low key bits — using
    # them would alias every request's region-0 onto slot 0
    nbits = (n_cl - 1).bit_length()
    if nbits == 0:
        # n_clusters=1 (the no-cluster ablation): the general expression
        # would shift by 32 — undefined for int32 in XLA — before the
        # `& 0` mask saves it; index slot 0 explicitly instead
        ci = jnp.int32(0)
    else:
        ci = jax.lax.shift_right_logical(
            ckey * jnp.int32(-1640531535), 32 - nbits) & (n_cl - 1)
    phys_cl = vtc.cl_phys[ci, block & (CLUSTER - 1)]
    # a cluster may predate the mapping of some of its 8 blocks (it then
    # holds FREE=-1 for them) — such entries fall through to the walk,
    # mirroring the paper's invalid-PTE handling
    cl_hit = ((~tc_hit) & vtc.cl_valid[ci] & (vtc.cl_tags[ci] == ckey)
              & (phys_cl >= 0))
    # cluster hit promotion (TLB-aware: -3 under pressure)
    dec = jnp.where(pressure, 3, 1)
    vtc = vtc._replace(cl_rrpv=vtc.cl_rrpv.at[ci].set(
        jnp.where(cl_hit, jnp.maximum(vtc.cl_rrpv[ci] - dec, 0),
                  vtc.cl_rrpv[ci])))

    # tier 3: radix walk
    need_walk = ~tc_hit & ~cl_hit
    phys_walk, hops, leaf_row = btab.walk(bt, req, block)
    bt2 = btab.note_walk(bt, leaf_row, hops >= 2)  # chained-gather walk = costly
    bt = jax.tree.map(lambda a, b: jnp.where(need_walk, b, a), bt, bt2)

    phys = jnp.where(tc_hit, vtc.tc_phys[s, w_hit],
                     jnp.where(cl_hit, phys_cl, phys_walk))

    # PTW-CP gate → install the full cluster of 8 neighbours.
    # Thresholds are refit for the serving domain exactly as the paper
    # refit its box from NN-2 (Fig. 16): our per-leaf-row counters are
    # lifetime counters, so the paper's cost≤12 upper bound (which filters
    # 500M-instr window pathologies) would permanently exclude every hot
    # row once its 4-bit counter saturates — only LOWER bounds survive the
    # refit, which is why ``gate`` carries (freq_min, cost_min) and no
    # upper edge.  Default box: freq≥1 ∧ cost≥1.
    f = bt.walk_freq[leaf_row].astype(jnp.int32)
    c = bt.walk_cost[leaf_row].astype(jnp.int32)
    pred = (f >= int(gate[0])) & (c >= int(gate[1]))
    install = need_walk & pred
    base = block & ~(CLUSTER - 1)
    neigh = base + jnp.arange(CLUSTER)
    nphys, _, _ = btab.walk_batch(bt, jnp.full((CLUSTER,), req), neigh)
    # TLB-aware eviction of the direct-mapped slot: under pressure an
    # existing *valid cluster with low RRPV* resists replacement, but a
    # blocked install AGES the slot (SRRIP semantics) so stale clusters
    # cannot squat forever
    resist = vtc.cl_valid[ci] & pressure & (vtc.cl_rrpv[ci] < RRIP_MAX)
    do_install = install & ~resist
    aged = jnp.minimum(vtc.cl_rrpv[ci]
                       + (install & resist).astype(jnp.int32), RRIP_MAX)
    vtc = vtc._replace(cl_rrpv=vtc.cl_rrpv.at[ci].set(aged))
    vtc = vtc._replace(
        cl_tags=vtc.cl_tags.at[ci].set(
            jnp.where(do_install, ckey, vtc.cl_tags[ci])),
        cl_phys=vtc.cl_phys.at[ci].set(
            jnp.where(do_install, nphys, vtc.cl_phys[ci])),
        cl_valid=vtc.cl_valid.at[ci].set(vtc.cl_valid[ci] | do_install),
        cl_rrpv=vtc.cl_rrpv.at[ci].set(
            jnp.where(do_install, jnp.where(pressure, 0, RRIP_MAX - 1),
                      vtc.cl_rrpv[ci])),
    )

    # TC refill (LRU victim) on any miss
    stamps = jnp.where(vtc.tc_valid[s], vtc.tc_stamp[s], -1)
    wv = jnp.argmin(stamps)
    miss = ~tc_hit
    vtc = vtc._replace(
        tc_tags=vtc.tc_tags.at[s, wv].set(
            jnp.where(miss, key, vtc.tc_tags[s, wv])),
        tc_phys=vtc.tc_phys.at[s, wv].set(
            jnp.where(miss, phys, vtc.tc_phys[s, wv])),
        tc_valid=vtc.tc_valid.at[s, wv].set(vtc.tc_valid[s, wv] | miss),
        tc_stamp=vtc.tc_stamp.at[s, wv].set(
            jnp.where(miss, now, vtc.tc_stamp[s, wv])),
        n_hit_tc=vtc.n_hit_tc + tc_hit.astype(jnp.int32),
        n_hit_cluster=vtc.n_hit_cluster + cl_hit.astype(jnp.int32),
        n_walk=vtc.n_walk + need_walk.astype(jnp.int32),
    )
    return vtc, bt, phys, jnp.where(tc_hit, 0, jnp.where(cl_hit, 1, 2))


def translate_batch(vtc: VTC, bt: btab.BlockTables, reqs, blocks, pressure,
                    valid=None, gate: tuple = (1, 1)):
    """Sequential (scan) batch translation — the scheduler-side path.

    ``valid`` (bool [n], optional) masks lanes out of the batch entirely:
    a masked lane touches NO state — no counters, no refills, no walk
    side effects — and reports ``phys = -1, src = -1``.  The serving
    engine uses this to keep dead slots from walking unmapped block 0
    every tick and polluting the pressure signal.
    """
    if valid is None:
        valid = jnp.ones(reqs.shape, jnp.bool_)

    def body(carry, rbv):
        v, b = carry
        req, block, ok = rbv[0], rbv[1], rbv[2].astype(jnp.bool_)
        v2, b2, phys, src = translate(v, b, req, block, pressure, gate)
        v = jax.tree.map(lambda old, new: jnp.where(ok, new, old), v, v2)
        b = jax.tree.map(lambda old, new: jnp.where(ok, new, old), b, b2)
        return (v, b), (jnp.where(ok, phys, -1), jnp.where(ok, src, -1))

    (vtc, bt), (phys, src) = jax.lax.scan(
        body, (vtc, bt),
        jnp.stack([reqs, blocks, valid.astype(reqs.dtype)], 1))
    return vtc, bt, phys, src


def _shootdown_masks(vtc: VTC, req):
    tmask = (vtc.tc_tags >> 20) == req
    cmask = (vtc.cl_tags >> 17) == req  # ckey = key>>3 ⇒ req bits at 17
    return tmask, cmask


def invalidation_counts(vtc: VTC, req):
    """How many live entries a shootdown of `req` would invalidate.

    Returns ``(n_tc, n_cluster)`` as int32 scalars (tracers under jit) —
    the serving engine feeds these to the ``serve.vtc.invalidate``
    counter, host-side only.
    """
    tmask, cmask = _shootdown_masks(vtc, req)
    return (jnp.sum((vtc.tc_valid & tmask).astype(jnp.int32)),
            jnp.sum((vtc.cl_valid & cmask).astype(jnp.int32)))


def invalidate_request(vtc: VTC, req) -> VTC:
    """Shootdown flow (paper §6): request eviction invalidates its TC
    entries and cluster pages by tag match on the request id."""
    tmask, cmask = _shootdown_masks(vtc, req)
    return vtc._replace(
        tc_valid=vtc.tc_valid & ~tmask,
        cl_valid=vtc.cl_valid & ~cmask,
    )


def stats(vtc: VTC) -> dict:
    """Host-side counter snapshot (plain ints/floats, safe to serialize).

    ``vtc_hit_rate`` is the paper's translation-reach headline for the
    serving tiers: the fraction of translations served WITHOUT a radix
    walk (TC hits + cluster hits).
    """
    hit_tc = int(vtc.n_hit_tc)
    hit_cl = int(vtc.n_hit_cluster)
    walks = int(vtc.n_walk)
    tot = max(hit_tc + hit_cl + walks, 1)
    return {
        "n_hit_tc": hit_tc,
        "n_hit_cluster": hit_cl,
        "n_walk": walks,
        "tc_hit_rate": hit_tc / tot,
        "cluster_hit_rate": hit_cl / tot,
        "walk_rate": walks / tot,
        "vtc_hit_rate": (hit_tc + hit_cl) / tot,
        "tc_occupancy": float(jnp.mean(vtc.tc_valid.astype(jnp.float32))),
        "cl_occupancy": float(jnp.mean(vtc.cl_valid.astype(jnp.float32))),
    }
