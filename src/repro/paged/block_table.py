"""Paged-KV block tables — the framework's page-table analogue.

Logical KV block (request r, block index b) → physical page in the HBM KV
pool, resolved through a 2-level radix table:

    directory[r, b >> FANOUT_BITS] → leaf page id
    leaf[leaf_page, b & FANOUT-1]  → physical KV page

Two chained HBM gathers per translation — the "page table walk" of the
serving stack (a 500K-token request has 4096 leaf entries; the directory
keeps resize/defrag O(1) like the OS PT it mirrors).  The Victima layer
(``translation_cache``) shortens this chain for hot, costly translations.

Pure-functional: tables are int32 arrays, updates return new arrays.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

FANOUT_BITS = 6
FANOUT = 1 << FANOUT_BITS          # 64 leaf entries per directory slot
TOKENS_PER_PAGE = 128
FREE = jnp.int32(-1)


class BlockTables(NamedTuple):
    directory: jax.Array   # int32 [R, max_dir]    → leaf row id (or FREE)
    leaves: jax.Array      # int32 [n_leaf_rows, FANOUT] → phys page (FREE)
    leaf_free: jax.Array   # int32 [n_leaf_rows]   1 = row free
    # walk-cost counters for the PTW-CP analogue (per leaf row)
    walk_freq: jax.Array   # uint8 [n_leaf_rows]
    walk_cost: jax.Array   # uint8 [n_leaf_rows]


def make(n_requests: int, max_blocks_per_req: int, n_leaf_rows: int
         ) -> BlockTables:
    max_dir = (max_blocks_per_req + FANOUT - 1) // FANOUT
    return BlockTables(
        directory=jnp.full((n_requests, max_dir), FREE, jnp.int32),
        leaves=jnp.full((n_leaf_rows, FANOUT), FREE, jnp.int32),
        leaf_free=jnp.ones((n_leaf_rows,), jnp.int32),
        walk_freq=jnp.zeros((n_leaf_rows,), jnp.uint8),
        walk_cost=jnp.zeros((n_leaf_rows,), jnp.uint8),
    )


def walk(bt: BlockTables, req: jax.Array, block: jax.Array):
    """Radix walk: 2 dependent gathers. Returns (phys_page, hops, leaf_row).
    hops = 2 normally; 1 if the directory slot is dead (fault path)."""
    dslot = block >> FANOUT_BITS
    leaf_row = bt.directory[req, dslot]
    ok = leaf_row >= 0
    phys = jnp.where(ok, bt.leaves[jnp.maximum(leaf_row, 0),
                                   block & (FANOUT - 1)], FREE)
    hops = jnp.where(ok, 2, 1)
    return phys, hops, jnp.maximum(leaf_row, 0)


def walk_batch(bt: BlockTables, reqs: jax.Array, blocks: jax.Array):
    return jax.vmap(lambda r, b: walk(bt, r, b))(reqs, blocks)


def map_block(bt: BlockTables, req, block, phys_page) -> BlockTables:
    """Map (req, block) → phys_page, allocating a leaf row if needed."""
    dslot = block >> FANOUT_BITS
    leaf_row = bt.directory[req, dslot]
    need_alloc = leaf_row < 0
    fresh = jnp.argmax(bt.leaf_free)            # first free row
    row = jnp.where(need_alloc, fresh, leaf_row)
    directory = bt.directory.at[req, dslot].set(row)
    leaf_free = bt.leaf_free.at[fresh].set(
        jnp.where(need_alloc, 0, bt.leaf_free[fresh]))
    leaves = bt.leaves.at[row, block & (FANOUT - 1)].set(phys_page)
    return bt._replace(directory=directory, leaves=leaves,
                       leaf_free=leaf_free)


def unmap_request(bt: BlockTables, req) -> BlockTables:
    """Release a finished request (the 'TLB shootdown' trigger).

    Invalid directory slots clamp to row 0, so all scatters must be
    order-independent (max/min), never plain writes."""
    rows = bt.directory[req]
    valid = rows >= 0
    rc = jnp.maximum(rows, 0)
    leaf_free = bt.leaf_free.at[rc].max(valid.astype(jnp.int32))
    big = jnp.int32(1 << 30)
    leaves = bt.leaves.at[rc].min(
        jnp.where(valid[:, None], FREE, big))
    return bt._replace(
        directory=bt.directory.at[req].set(FREE),
        leaves=leaves, leaf_free=leaf_free)


def note_walk(bt: BlockTables, leaf_row, had_fault) -> BlockTables:
    """PTW-CP counter update (3-bit freq, 4-bit cost, saturating —
    identical bit-budget to the paper's PTE-embedded counters)."""
    f = jnp.minimum(bt.walk_freq[leaf_row].astype(jnp.int32) + 1, 7)
    c = jnp.minimum(bt.walk_cost[leaf_row].astype(jnp.int32)
                    + jnp.asarray(had_fault).astype(jnp.int32), 15)
    return bt._replace(
        walk_freq=bt.walk_freq.at[leaf_row].set(f.astype(jnp.uint8)),
        walk_cost=bt.walk_cost.at[leaf_row].set(c.astype(jnp.uint8)))
