"""Batched serving engine with Victima-paged KV.

Continuous-batching style: a fixed pool of request slots; arriving
requests prefill into paged KV (pages allocated from the pool), decode
proceeds in lock-step over active slots, finished slots are shot down
(``translation_cache.invalidate_request`` + ``block_table.unmap_request``)
and refilled.  Translation of logical→physical KV pages goes through the
VTC (TC hit / cluster hit / radix walk) — the serving-side embodiment of
the paper (DESIGN.md §2.2); hit-rate stats come back with every batch.

Correctness invariants the serving load harness leans on:

  * **No aliasing under exhaustion.**  ``admit`` and the decode-tick
    ``grow`` only take a page when one is actually free; an exhausted
    pool rejects the admission / defers the growth (and bumps the
    ``serve.pool_exhausted`` accounting) instead of double-mapping
    whatever ``argmax`` of an all-zero free vector points at (page 0).
  * **Dead slots are invisible.**  Only live, un-stalled slots enter the
    per-tick translation batch (``translate_batch(..., valid=...)``), so
    parked slots cannot walk unmapped block 0 and pollute the pressure
    signal or the VTC counters.
  * **Pressure is a sampled window.**  The paper's L2-TLB miss-rate
    signal (§5.3) is sampled over an epoch, not accumulated forever:
    ``EngineState`` carries a per-epoch walk/total window and latches
    ``pressure`` at each epoch boundary, so pressure decays when the
    working set shrinks.

All engine/batch-step functions are jit/scan-safe; the ``scope``
parameters on the host-side telemetry entry points (``retire``,
``decode_step``, ``stats``) suffix registry metric names with
``[scope]`` so multiple engines in one process (e.g. the cluster vs
no-cluster ablation) do not share counters.

The numerics path uses the dense models' decode_step on gathered pages
(CPU/functional mode); on TPU the gather is replaced by the Pallas
``paged_attention`` kernel whose BlockSpec index maps consume the same
translated tables.
"""
from __future__ import annotations

import dataclasses
import time
from typing import NamedTuple

import jax
import jax.numpy as jnp

import repro.obs as obs
from repro.paged import block_table as btab
from repro.paged import translation_cache as vtc_mod


def scoped(name: str, scope: str | None) -> str:
    """Registry metric name for one engine instance: ``name[scope]``.

    The obs registry is process-global; without a scope two engines
    (e.g. benchmarks/serving.py's VTC vs no-cluster ablation) would
    interleave ``inc_to`` samples and report the max of both."""
    return f"{name}[{scope}]" if scope else name


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    n_slots: int = 8                 # concurrent requests
    max_blocks_per_req: int = 64     # × TOKENS_PER_PAGE tokens
    n_pool_pages: int = 512
    n_leaf_rows: int = 64
    tc_sets: int = 16
    tc_ways: int = 4
    n_clusters: int = 64
    pressure_thresh: float = 0.3     # windowed walk rate → "pressure"
    pressure_epoch: int = 64         # ticks per pressure sampling window
    # PTW-CP cluster-install gate (freq_min, cost_min) — lower bounds
    # only (see translation_cache.translate); tuned from the simulator's
    # PTW-CP sweep by serve.load.tune_gate
    gate_freq_min: int = 1
    gate_cost_min: int = 1

    def __post_init__(self):
        # surface the VTC's power-of-two indexing requirement at config
        # construction (vtc_mod.make re-checks, but the engine config is
        # the user-facing knob)
        if not vtc_mod._pow2(self.tc_sets):
            raise ValueError(
                f"EngineConfig.tc_sets must be a power of two, "
                f"got {self.tc_sets}")
        if not vtc_mod._pow2(self.n_clusters):
            raise ValueError(
                f"EngineConfig.n_clusters must be a power of two, "
                f"got {self.n_clusters}")
        if self.pressure_epoch < 1:
            raise ValueError(
                f"EngineConfig.pressure_epoch must be >= 1, "
                f"got {self.pressure_epoch}")
        if self.gate_freq_min < 0 or self.gate_cost_min < 0:
            raise ValueError(
                f"EngineConfig gate thresholds must be >= 0, got "
                f"({self.gate_freq_min}, {self.gate_cost_min})")


class EngineState(NamedTuple):
    bt: btab.BlockTables
    vtc: vtc_mod.VTC
    page_free: jax.Array      # int32 [n_pool_pages] 1=free
    slot_len: jax.Array       # int32 [n_slots] tokens decoded
    slot_live: jax.Array      # bool  [n_slots]
    tick: jax.Array           # int32 decode ticks since init
    win_walk: jax.Array       # int32 walks in the current pressure epoch
    win_total: jax.Array      # int32 translations in the current epoch
    pressure: jax.Array       # bool  latched at the last epoch boundary
    n_pool_stall: jax.Array   # int32 pool-exhausted events (cumulative)


def init(cfg: EngineConfig) -> EngineState:
    return EngineState(
        bt=btab.make(cfg.n_slots, cfg.max_blocks_per_req, cfg.n_leaf_rows),
        vtc=vtc_mod.make(cfg.tc_sets, cfg.tc_ways, cfg.n_clusters),
        page_free=jnp.ones((cfg.n_pool_pages,), jnp.int32),
        slot_len=jnp.zeros((cfg.n_slots,), jnp.int32),
        slot_live=jnp.zeros((cfg.n_slots,), jnp.bool_),
        tick=jnp.int32(0),
        win_walk=jnp.int32(0),
        win_total=jnp.int32(0),
        pressure=jnp.bool_(False),
        n_pool_stall=jnp.int32(0),
    )


def admit(st: EngineState, slot, prompt_blocks):
    """Admit a request into `slot`: allocate + map its prompt pages.

    Returns ``(state, ok)``.  The admission is ATOMIC against pool
    exhaustion: when fewer than ``prompt_blocks`` pages are free (or the
    slot is already live, or the request is empty/oversized) NOTHING is
    allocated and ``ok`` is False — the caller re-queues the request.
    Without the guard an exhausted pool would map every remaining block
    onto ``argmax(free) == 0``, aliasing page 0 across requests.

    jit-safe: `slot` and `prompt_blocks` may be tracers (the scan runs a
    fixed ``capacity`` iterations, masked by ``b < prompt_blocks``).
    """
    capacity = st.bt.directory.shape[1] * btab.FANOUT
    nb = jnp.int32(prompt_blocks)
    slot = jnp.int32(slot)
    ok = ((nb > 0) & (nb <= capacity)
          & (jnp.sum(st.page_free) >= nb)
          & ~st.slot_live[slot])

    def body(carry, b):
        bt, free = carry
        take = ok & (b < nb)
        page = jnp.argmax(free)
        free = jnp.where(take, free.at[page].set(0), free)
        bt2 = btab.map_block(bt, slot, b, page)
        bt = jax.tree.map(lambda a, c: jnp.where(take, c, a), bt, bt2)
        return (bt, free), None

    (bt, free), _ = jax.lax.scan(
        body, (st.bt, st.page_free), jnp.arange(capacity))
    st = st._replace(
        bt=bt, page_free=free,
        slot_len=st.slot_len.at[slot].set(
            jnp.where(ok, nb * btab.TOKENS_PER_PAGE, st.slot_len[slot])),
        slot_live=st.slot_live.at[slot].set(st.slot_live[slot] | ok))
    return st, ok


def admit_where(st: EngineState, prompt_blocks):
    """Batch admission: try ``prompt_blocks[i]`` into every slot `i`
    (0 = no request for that slot).  Sequential scan, so the free-page
    guard stays atomic across slots.  Returns ``(state, oks[n_slots])``.
    """
    def body(s, i):
        s, ok = admit(s, i, prompt_blocks[i])
        return s, ok
    st, oks = jax.lax.scan(body, st,
                           jnp.arange(st.slot_len.shape[0]))
    return st, oks


def _retire_one(st: EngineState, slot):
    """Pure shootdown of one slot. Returns (state, n_invalidated)."""
    slot = jnp.int32(slot)
    rows = st.bt.directory[slot]
    # free the physical pages reachable from this request's leaves
    valid_rows = rows >= 0
    pages = st.bt.leaves[jnp.maximum(rows, 0)]           # [dir, FANOUT]
    pmask = (pages >= 0) & valid_rows[:, None]
    free = st.page_free.at[jnp.maximum(pages, 0).reshape(-1)].max(
        pmask.reshape(-1).astype(jnp.int32))
    bt = btab.unmap_request(st.bt, slot)
    n_tc, n_cl = vtc_mod.invalidation_counts(st.vtc, slot)
    vtc = vtc_mod.invalidate_request(st.vtc, slot)
    st = st._replace(
        bt=bt, vtc=vtc, page_free=free,
        slot_len=st.slot_len.at[slot].set(0),
        slot_live=st.slot_live.at[slot].set(False))
    return st, n_tc + n_cl


def retire(st: EngineState, slot, scope: str | None = None) -> EngineState:
    """Finish a request: shootdown — unmap pages, invalidate translations."""
    st, n_inval = _retire_one(st, slot)
    # tracer-safe: under jit these counts are tracers and the registry
    # skips the bump — host-path retires (the scheduler loop) do count
    obs.count(scoped(obs.names.CTR_VTC_INVALIDATE, scope), n_inval)
    return st


def retire_where(st: EngineState, mask):
    """Batch shootdown of every slot where ``mask`` is True.

    Returns ``(state, n_invalidated)`` with the total invalidation count
    as an int32 scalar (a tracer under jit — the load harness fetches it
    and feeds the scoped counter host-side).
    """
    def body(s, i):
        s2, n = _retire_one(s, i)
        s = jax.tree.map(lambda a, b: jnp.where(mask[i], b, a), s, s2)
        return s, jnp.where(mask[i], n, 0)
    st, ns = jax.lax.scan(body, st, jnp.arange(st.slot_len.shape[0]))
    return st, jnp.sum(ns)


def decode_translate(st: EngineState, cfg: EngineConfig):
    """One decode tick's translation work: every live slot translates the
    block holding its current position (+ appends a page on boundary).
    Returns (state, phys_pages [n_slots], src [n_slots]).

    Slots that hit a page boundary with an EXHAUSTED pool stall this
    tick (no growth, no translation, no length advance — retried next
    tick); parked (non-live) slots never enter the translation batch.
    ``src`` is -1 for stalled/parked slots.
    """
    n = st.slot_len.shape[0]
    pos = st.slot_len
    blocks = pos // btab.TOKENS_PER_PAGE
    # page-boundary: map a fresh page where needed — IF one is free;
    # an exhausted pool defers the growth instead of aliasing page 0
    def grow(carry, i):
        bt, free = carry
        need = st.slot_live[i] & (pos[i] % btab.TOKENS_PER_PAGE == 0)
        have = jnp.sum(free) > 0
        take = need & have
        page = jnp.argmax(free)
        free = jnp.where(take, free.at[page].set(0), free)
        bt2 = btab.map_block(bt, i, blocks[i], page)
        bt = jax.tree.map(lambda a, b: jnp.where(take, b, a), bt, bt2)
        return (bt, free), need & ~have
    (bt, free), stalled = jax.lax.scan(
        grow, (st.bt, st.page_free), jnp.arange(n))

    active = st.slot_live & ~stalled
    # paged attention reads the WHOLE context per token — translate the
    # current block plus sampled context blocks (the re-read stream where
    # the Victima tiers earn their keep).  Dead/stalled slots are MASKED
    # out of the batch: they touch no VTC state and report src = -1.
    h1 = (pos * 48271 % jnp.maximum(blocks, 1)).astype(jnp.int32)
    h2 = ((pos + 7) * 40503 % jnp.maximum(blocks, 1)).astype(jnp.int32)
    reqs = jnp.concatenate([jnp.arange(n)] * 3)
    blks = jnp.concatenate([blocks, h1, h2])
    valid = jnp.concatenate(
        [active, active & (blocks > 0), active & (blocks > 0)])
    vtc, bt, phys_all, src_all = vtc_mod.translate_batch(
        st.vtc, bt, reqs, blks, st.pressure, valid=valid,
        gate=(cfg.gate_freq_min, cfg.gate_cost_min))
    phys, src = phys_all[:n], src_all[:n]

    # sampled-window pressure (paper §5.3): accumulate this tick's
    # walk/total into the epoch window; at the epoch boundary latch
    # pressure from the WINDOW's walk rate and reset — so pressure can
    # decay when the working set shrinks, unlike the lifetime counters
    win_walk = st.win_walk + jnp.sum((src_all == 2).astype(jnp.int32))
    win_total = st.win_total + jnp.sum((src_all >= 0).astype(jnp.int32))
    tick = st.tick + 1
    boundary = (tick % cfg.pressure_epoch) == 0
    rate = (win_walk.astype(jnp.float32)
            / jnp.maximum(win_total, 1).astype(jnp.float32))
    pressure = jnp.where(boundary, rate > cfg.pressure_thresh, st.pressure)
    win_walk = jnp.where(boundary, 0, win_walk)
    win_total = jnp.where(boundary, 0, win_total)

    st = st._replace(
        bt=bt, vtc=vtc, page_free=free,
        slot_len=jnp.where(active, pos + 1, pos),
        tick=tick, win_walk=win_walk, win_total=win_total,
        pressure=pressure,
        n_pool_stall=st.n_pool_stall
        + jnp.sum(stalled.astype(jnp.int32)))
    return st, phys, src


def decode_step(st: EngineState, cfg: EngineConfig, fn=None,
                scope: str | None = None):
    """One TIMED decode tick: the instrumented serving entry point.

    Runs ``fn(state)`` (default: ``decode_translate`` under this `cfg`;
    pass a jitted closure for hot loops) inside a ``serve.decode_step``
    span, blocks on the results so the measured latency is real device
    time, and feeds the obs registry: the decode-step latency histogram
    and the step counter the serving load harness reports from.
    """
    if fn is None:
        fn = lambda s: decode_translate(s, cfg)  # noqa: E731
    with obs.span(obs.names.SPAN_DECODE_STEP):
        t0 = time.perf_counter()
        out = fn(st)
        jax.block_until_ready(out)
        obs.observe(scoped(obs.names.HIST_DECODE_STEP_S, scope),
                    time.perf_counter() - t0)
    obs.count(scoped(obs.names.CTR_DECODE_STEPS, scope))
    return out


def stats(st: EngineState, scope: str | None = None) -> dict:
    """Engine-level snapshot, routed through the obs registry.

    VTC counters live in device state (cumulative across the request's
    jitted steps), so sampling here raises the registry counters
    monotonically (``inc_to``) rather than double-counting; pool/slot
    occupancy land as gauges.  Pass ``scope`` when more than one engine
    lives in the process — registry names are suffixed ``[scope]`` so
    engines never share counters (and ``inc_to`` monotonicity holds per
    engine, not across the max of several).
    """
    v = vtc_mod.stats(st.vtc)
    pages_free = int(jnp.sum(st.page_free))
    slot_occ = float(jnp.mean(st.slot_live.astype(jnp.float32)))
    pool_stall = int(st.n_pool_stall)
    obs.REGISTRY.inc_to(
        scoped(obs.names.CTR_VTC_HIT_TC, scope), v["n_hit_tc"])
    obs.REGISTRY.inc_to(
        scoped(obs.names.CTR_VTC_HIT_CLUSTER, scope), v["n_hit_cluster"])
    obs.REGISTRY.inc_to(
        scoped(obs.names.CTR_VTC_WALK, scope), v["n_walk"])
    obs.REGISTRY.inc_to(
        scoped(obs.names.CTR_POOL_EXHAUSTED, scope), pool_stall)
    obs.gauge(scoped(obs.names.GAUGE_PAGES_FREE, scope), pages_free)
    obs.gauge(scoped(obs.names.GAUGE_SLOT_OCCUPANCY, scope), slot_occ)
    return {
        "tc_hit_rate": v["tc_hit_rate"],
        "cluster_hit_rate": v["cluster_hit_rate"],
        "walk_rate": v["walk_rate"],
        "vtc_hit_rate": v["vtc_hit_rate"],
        "pages_free": pages_free,
        "slot_occupancy": slot_occ,
        "pool_stall": pool_stall,
        "pressure": bool(st.pressure),
        "invalidate_count": obs.REGISTRY.counter(
            scoped(obs.names.CTR_VTC_INVALIDATE, scope)),
    }
