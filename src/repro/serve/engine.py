"""Batched serving engine with Victima-paged KV.

Continuous-batching style: a fixed pool of request slots; arriving
requests prefill into paged KV (pages allocated from the pool), decode
proceeds in lock-step over active slots, finished slots are shot down
(``translation_cache.invalidate_request`` + ``block_table.unmap_request``)
and refilled.  Translation of logical→physical KV pages goes through the
VTC (TC hit / cluster hit / radix walk) — the serving-side embodiment of
the paper (DESIGN.md §2.2); hit-rate stats come back with every batch.

The numerics path uses the dense models' decode_step on gathered pages
(CPU/functional mode); on TPU the gather is replaced by the Pallas
``paged_attention`` kernel whose BlockSpec index maps consume the same
translated tables.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.paged import block_table as btab
from repro.paged import translation_cache as vtc_mod


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    n_slots: int = 8                 # concurrent requests
    max_blocks_per_req: int = 64     # × TOKENS_PER_PAGE tokens
    n_pool_pages: int = 512
    n_leaf_rows: int = 64
    tc_sets: int = 16
    tc_ways: int = 4
    n_clusters: int = 64
    pressure_thresh: float = 0.3     # TC miss rate → "translation pressure"


class EngineState(NamedTuple):
    bt: btab.BlockTables
    vtc: vtc_mod.VTC
    page_free: jax.Array      # int32 [n_pool_pages] 1=free
    slot_len: jax.Array       # int32 [n_slots] tokens decoded
    slot_live: jax.Array      # bool  [n_slots]


def init(cfg: EngineConfig) -> EngineState:
    return EngineState(
        bt=btab.make(cfg.n_slots, cfg.max_blocks_per_req, cfg.n_leaf_rows),
        vtc=vtc_mod.make(cfg.tc_sets, cfg.tc_ways, cfg.n_clusters),
        page_free=jnp.ones((cfg.n_pool_pages,), jnp.int32),
        slot_len=jnp.zeros((cfg.n_slots,), jnp.int32),
        slot_live=jnp.zeros((cfg.n_slots,), jnp.bool_),
    )


def admit(st: EngineState, slot: int, prompt_blocks: int) -> EngineState:
    """Admit a request into `slot`: allocate + map its prompt pages."""
    def body(carry, b):
        bt, free = carry
        page = jnp.argmax(free)            # first free page
        free = free.at[page].set(0)
        bt = btab.map_block(bt, jnp.int32(slot), b, page)
        return (bt, free), page

    (bt, free), _ = jax.lax.scan(
        body, (st.bt, st.page_free), jnp.arange(prompt_blocks))
    return st._replace(
        bt=bt, page_free=free,
        slot_len=st.slot_len.at[slot].set(
            prompt_blocks * btab.TOKENS_PER_PAGE),
        slot_live=st.slot_live.at[slot].set(True))


def retire(st: EngineState, slot: int) -> EngineState:
    """Finish a request: shootdown — unmap pages, invalidate translations."""
    rows = st.bt.directory[slot]
    # free the physical pages reachable from this request's leaves
    valid_rows = rows >= 0
    pages = st.bt.leaves[jnp.maximum(rows, 0)]           # [dir, FANOUT]
    pmask = (pages >= 0) & valid_rows[:, None]
    free = st.page_free.at[jnp.maximum(pages, 0).reshape(-1)].max(
        pmask.reshape(-1).astype(jnp.int32))
    bt = btab.unmap_request(st.bt, jnp.int32(slot))
    vtc = vtc_mod.invalidate_request(st.vtc, jnp.int32(slot))
    return st._replace(
        bt=bt, vtc=vtc, page_free=free,
        slot_len=st.slot_len.at[slot].set(0),
        slot_live=st.slot_live.at[slot].set(False))


def decode_translate(st: EngineState, cfg: EngineConfig):
    """One decode tick's translation work: every live slot translates the
    block holding its current position (+ appends a page on boundary).
    Returns (state, phys_pages [n_slots], src [n_slots])."""
    n = st.slot_len.shape[0]
    pos = st.slot_len
    blocks = pos // btab.TOKENS_PER_PAGE
    # page-boundary: map a fresh page where needed
    def grow(carry, i):
        bt, free = carry
        need = st.slot_live[i] & (pos[i] % btab.TOKENS_PER_PAGE == 0)
        page = jnp.argmax(free)
        free = jnp.where(need, free.at[page].set(0), free)
        bt2 = btab.map_block(bt, i, blocks[i], page)
        bt = jax.tree.map(lambda a, b: jnp.where(need, b, a), bt, bt2)
        return (bt, free), None
    (bt, free), _ = jax.lax.scan(grow, (st.bt, st.page_free), jnp.arange(n))

    walks = st.vtc.n_walk
    hits = st.vtc.n_hit_tc
    total = jnp.maximum(walks + hits + st.vtc.n_hit_cluster, 1)
    pressure = (walks.astype(jnp.float32) / total.astype(jnp.float32)
                > cfg.pressure_thresh)
    # paged attention reads the WHOLE context per token — translate the
    # current block plus sampled context blocks (the re-read stream where
    # the Victima tiers earn their keep)
    h1 = (pos * 48271 % jnp.maximum(blocks, 1)).astype(jnp.int32)
    h2 = ((pos + 7) * 40503 % jnp.maximum(blocks, 1)).astype(jnp.int32)
    reqs = jnp.concatenate([jnp.arange(n)] * 3)
    blks = jnp.concatenate([blocks, h1, h2])
    vtc, bt, phys_all, src_all = vtc_mod.translate_batch(
        st.vtc, bt, reqs, blks, pressure)
    phys, src = phys_all[:n], src_all[:n]
    st = st._replace(bt=bt, vtc=vtc, page_free=free,
                     slot_len=jnp.where(st.slot_live, pos + 1, pos))
    return st, phys, src


def stats(st: EngineState) -> dict:
    v = st.vtc
    tot = max(int(v.n_hit_tc + v.n_hit_cluster + v.n_walk), 1)
    return {
        "tc_hit_rate": float(v.n_hit_tc) / tot,
        "cluster_hit_rate": float(v.n_hit_cluster) / tot,
        "walk_rate": float(v.n_walk) / tot,
        "pages_free": int(jnp.sum(st.page_free)),
    }
