"""Batched serving engine with Victima-paged KV.

Continuous-batching style: a fixed pool of request slots; arriving
requests prefill into paged KV (pages allocated from the pool), decode
proceeds in lock-step over active slots, finished slots are shot down
(``translation_cache.invalidate_request`` + ``block_table.unmap_request``)
and refilled.  Translation of logical→physical KV pages goes through the
VTC (TC hit / cluster hit / radix walk) — the serving-side embodiment of
the paper (DESIGN.md §2.2); hit-rate stats come back with every batch.

The numerics path uses the dense models' decode_step on gathered pages
(CPU/functional mode); on TPU the gather is replaced by the Pallas
``paged_attention`` kernel whose BlockSpec index maps consume the same
translated tables.
"""
from __future__ import annotations

import dataclasses
import time
from typing import NamedTuple

import jax
import jax.numpy as jnp

import repro.obs as obs
from repro.paged import block_table as btab
from repro.paged import translation_cache as vtc_mod


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    n_slots: int = 8                 # concurrent requests
    max_blocks_per_req: int = 64     # × TOKENS_PER_PAGE tokens
    n_pool_pages: int = 512
    n_leaf_rows: int = 64
    tc_sets: int = 16
    tc_ways: int = 4
    n_clusters: int = 64
    pressure_thresh: float = 0.3     # TC miss rate → "translation pressure"


class EngineState(NamedTuple):
    bt: btab.BlockTables
    vtc: vtc_mod.VTC
    page_free: jax.Array      # int32 [n_pool_pages] 1=free
    slot_len: jax.Array       # int32 [n_slots] tokens decoded
    slot_live: jax.Array      # bool  [n_slots]


def init(cfg: EngineConfig) -> EngineState:
    return EngineState(
        bt=btab.make(cfg.n_slots, cfg.max_blocks_per_req, cfg.n_leaf_rows),
        vtc=vtc_mod.make(cfg.tc_sets, cfg.tc_ways, cfg.n_clusters),
        page_free=jnp.ones((cfg.n_pool_pages,), jnp.int32),
        slot_len=jnp.zeros((cfg.n_slots,), jnp.int32),
        slot_live=jnp.zeros((cfg.n_slots,), jnp.bool_),
    )


def admit(st: EngineState, slot: int, prompt_blocks: int) -> EngineState:
    """Admit a request into `slot`: allocate + map its prompt pages."""
    def body(carry, b):
        bt, free = carry
        page = jnp.argmax(free)            # first free page
        free = free.at[page].set(0)
        bt = btab.map_block(bt, jnp.int32(slot), b, page)
        return (bt, free), page

    (bt, free), _ = jax.lax.scan(
        body, (st.bt, st.page_free), jnp.arange(prompt_blocks))
    return st._replace(
        bt=bt, page_free=free,
        slot_len=st.slot_len.at[slot].set(
            prompt_blocks * btab.TOKENS_PER_PAGE),
        slot_live=st.slot_live.at[slot].set(True))


def retire(st: EngineState, slot: int) -> EngineState:
    """Finish a request: shootdown — unmap pages, invalidate translations."""
    rows = st.bt.directory[slot]
    # free the physical pages reachable from this request's leaves
    valid_rows = rows >= 0
    pages = st.bt.leaves[jnp.maximum(rows, 0)]           # [dir, FANOUT]
    pmask = (pages >= 0) & valid_rows[:, None]
    free = st.page_free.at[jnp.maximum(pages, 0).reshape(-1)].max(
        pmask.reshape(-1).astype(jnp.int32))
    bt = btab.unmap_request(st.bt, jnp.int32(slot))
    n_tc, n_cl = vtc_mod.invalidation_counts(st.vtc, jnp.int32(slot))
    # tracer-safe: under jit these counts are tracers and the registry
    # skips the bump — host-path retires (the scheduler loop) do count
    obs.count(obs.names.CTR_VTC_INVALIDATE, n_tc + n_cl)
    vtc = vtc_mod.invalidate_request(st.vtc, jnp.int32(slot))
    return st._replace(
        bt=bt, vtc=vtc, page_free=free,
        slot_len=st.slot_len.at[slot].set(0),
        slot_live=st.slot_live.at[slot].set(False))


def decode_translate(st: EngineState, cfg: EngineConfig):
    """One decode tick's translation work: every live slot translates the
    block holding its current position (+ appends a page on boundary).
    Returns (state, phys_pages [n_slots], src [n_slots])."""
    n = st.slot_len.shape[0]
    pos = st.slot_len
    blocks = pos // btab.TOKENS_PER_PAGE
    # page-boundary: map a fresh page where needed
    def grow(carry, i):
        bt, free = carry
        need = st.slot_live[i] & (pos[i] % btab.TOKENS_PER_PAGE == 0)
        page = jnp.argmax(free)
        free = jnp.where(need, free.at[page].set(0), free)
        bt2 = btab.map_block(bt, i, blocks[i], page)
        bt = jax.tree.map(lambda a, b: jnp.where(need, b, a), bt, bt2)
        return (bt, free), None
    (bt, free), _ = jax.lax.scan(grow, (st.bt, st.page_free), jnp.arange(n))

    walks = st.vtc.n_walk
    hits = st.vtc.n_hit_tc
    total = jnp.maximum(walks + hits + st.vtc.n_hit_cluster, 1)
    pressure = (walks.astype(jnp.float32) / total.astype(jnp.float32)
                > cfg.pressure_thresh)
    # paged attention reads the WHOLE context per token — translate the
    # current block plus sampled context blocks (the re-read stream where
    # the Victima tiers earn their keep)
    h1 = (pos * 48271 % jnp.maximum(blocks, 1)).astype(jnp.int32)
    h2 = ((pos + 7) * 40503 % jnp.maximum(blocks, 1)).astype(jnp.int32)
    reqs = jnp.concatenate([jnp.arange(n)] * 3)
    blks = jnp.concatenate([blocks, h1, h2])
    vtc, bt, phys_all, src_all = vtc_mod.translate_batch(
        st.vtc, bt, reqs, blks, pressure)
    phys, src = phys_all[:n], src_all[:n]
    st = st._replace(bt=bt, vtc=vtc, page_free=free,
                     slot_len=jnp.where(st.slot_live, pos + 1, pos))
    return st, phys, src


def decode_step(st: EngineState, cfg: EngineConfig, fn=None):
    """One TIMED decode tick: the instrumented serving entry point.

    Runs ``fn(state)`` (default: ``decode_translate`` under this `cfg`;
    pass a jitted closure for hot loops) inside a ``serve.decode_step``
    span, blocks on the results so the measured latency is real device
    time, and feeds the obs registry: the decode-step latency histogram
    and the step counter the serving load harness will report from.
    """
    if fn is None:
        fn = lambda s: decode_translate(s, cfg)  # noqa: E731
    with obs.span(obs.names.SPAN_DECODE_STEP):
        t0 = time.perf_counter()
        out = fn(st)
        jax.block_until_ready(out)
        obs.observe(obs.names.HIST_DECODE_STEP_S,
                    time.perf_counter() - t0)
    obs.count(obs.names.CTR_DECODE_STEPS)
    return out


def stats(st: EngineState) -> dict:
    """Engine-level snapshot, routed through the obs registry.

    VTC counters live in device state (cumulative across the request's
    jitted steps), so sampling here raises the registry counters
    monotonically (``inc_to``) rather than double-counting; pool/slot
    occupancy land as gauges.  Keys extend the legacy dict with the
    paper-facing ``vtc_hit_rate`` (walk-free translation fraction) and
    ``invalidate_count`` (shootdown work observed by ``retire``).
    """
    v = vtc_mod.stats(st.vtc)
    pages_free = int(jnp.sum(st.page_free))
    slot_occ = float(jnp.mean(st.slot_live.astype(jnp.float32)))
    obs.REGISTRY.inc_to(obs.names.CTR_VTC_HIT_TC, v["n_hit_tc"])
    obs.REGISTRY.inc_to(obs.names.CTR_VTC_HIT_CLUSTER, v["n_hit_cluster"])
    obs.REGISTRY.inc_to(obs.names.CTR_VTC_WALK, v["n_walk"])
    obs.gauge(obs.names.GAUGE_PAGES_FREE, pages_free)
    obs.gauge(obs.names.GAUGE_SLOT_OCCUPANCY, slot_occ)
    return {
        "tc_hit_rate": v["tc_hit_rate"],
        "cluster_hit_rate": v["cluster_hit_rate"],
        "walk_rate": v["walk_rate"],
        "vtc_hit_rate": v["vtc_hit_rate"],
        "pages_free": pages_free,
        "slot_occupancy": slot_occ,
        "invalidate_count": obs.REGISTRY.counter(
            obs.names.CTR_VTC_INVALIDATE),
    }
