"""Serving load harness: production-traffic replay through a sharded engine.

The sim↔serving loop closed (ROADMAP): replay open-loop request arrival
traces — Poisson and bursty-diurnal, request-length mixes drawn from the
model shape configs (``repro.configs.base.SHAPES``) — through ``lanes``
independent serving engines whose slot pools, KV page pools, and VTCs
ride a leading lane axis sharded over a 1-D ``("lane",)`` device mesh
(``sim.parallel.shard_lanes``).  A host-side scheduler loop assigns
arrivals to lanes/slots and drives ONE jitted+shard_mapped device step
per tick (admit → decode/translate → retire, fused), under ``repro.obs``
spans.

Observability contract (the BENCH_serve analogue of BENCH_sweep's
schema-5 discipline): each run opens a ``serve.load_run`` span; every
per-tick ``serve.decode_step`` span and ``serve.*`` count record is its
descendant, and the run's SERVE_PERF record is derived from the tracer's
events by ``obs.report.serve_record`` — the same function the CLI
applies to the JSONL file, so ``report --check BENCH_serve.json`` is
bit-exact.  Registry metrics are scoped per run (``name[scope]``, see
``engine.scoped``); trace counts keep the declared base names because
run isolation in the trace comes from span parentage.

``tune_gate`` is the first place the reproduction feeds the production
path: it fits the paper's PTW-CP comparator box on the simulator's
collect-mode features (``ptwcp_nn.fit_box``) and maps its lower edges
onto the engine's cluster-install gate.
"""
from __future__ import annotations

import collections
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

import repro.obs as obs
from repro.configs.base import SHAPES
from repro.obs import names
from repro.paged import block_table as btab
from repro.serve import engine
from repro.sim import parallel

# BENCH_serve records, one per completed run — appended ONLY via
# obs.report.serve_record (the OB001 serve closure checks this), exactly
# like sim.runner.LADDER_PERF for ladder fills.
SERVE_PERF: list[dict] = []


# ------------------------------------------------------- arrival traces

@dataclasses.dataclass(frozen=True)
class Request:
    arrive_tick: int
    prompt_blocks: int     # KV pages to prefill at admission
    decode_tokens: int     # decode ticks before the request finishes
    kind: str = ""         # shape-config name the length was drawn from


# shape-name → arrival weight: short train/chat-sized requests dominate,
# long-context requests are the rare tail — the mix that actually
# exercises both the TC (hot short contexts) and the cluster tier
# (block-dense long contexts)
MIX_WEIGHTS = {"train_4k": 0.45, "prefill_32k": 0.25,
               "decode_32k": 0.25, "long_500k": 0.05}


def length_mix(cfg: engine.EngineConfig, scale: int = 128):
    """(name, prompt_blocks, decode_tokens, weight) per shape config.

    Shape sequence lengths map to engine-sized page counts via
    ``seq_len / TOKENS_PER_PAGE / scale`` (clamped to the engine's
    per-request capacity): the 500K-token long-context shape lands at
    the biggest admissible request, the 4K chat shape at the smallest.
    Decode length scales with the shape kind — prefill-dominated shapes
    finish in a few ticks, decode-dominated ones hold their slot longer.
    """
    cap = max(cfg.max_blocks_per_req - 1, 1)
    mix = []
    for name, sh in SHAPES.items():
        blocks = max(1, min(cap, sh.seq_len // btab.TOKENS_PER_PAGE // scale))
        decode = {"train": 4, "prefill": 6, "decode": 16}[sh.kind]
        mix.append((name, blocks, decode, MIX_WEIGHTS.get(name, 0.1)))
    return mix


def _mix_rng(mix, seed):
    p = np.asarray([m[3] for m in mix], np.float64)
    return np.random.default_rng(seed), p / p.sum()


def poisson_trace(rate: float, n_ticks: int,
                  cfg: engine.EngineConfig | None = None,
                  seed: int = 0, scale: int = 128) -> list[Request]:
    """Open-loop Poisson arrivals at ``rate`` requests/tick."""
    cfg = cfg or engine.EngineConfig()
    mix = length_mix(cfg, scale)
    rng, p = _mix_rng(mix, seed)
    out: list[Request] = []
    for t in range(n_ticks):
        for _ in range(rng.poisson(rate)):
            name, blocks, decode, _w = mix[rng.choice(len(mix), p=p)]
            out.append(Request(t, blocks, decode, name))
    return out


def diurnal_trace(rate: float, n_ticks: int,
                  cfg: engine.EngineConfig | None = None,
                  seed: int = 0, scale: int = 128,
                  period: int | None = None,
                  burst: float = 3.0, burst_prob: float = 0.02,
                  burst_len: int = 8) -> list[Request]:
    """Bursty diurnal arrivals: a sinusoidal day/night envelope over the
    base ``rate`` plus random ``burst``× spikes a few ticks long — the
    open-loop worst case that actually exhausts the page pool."""
    cfg = cfg or engine.EngineConfig()
    mix = length_mix(cfg, scale)
    rng, p = _mix_rng(mix, seed)
    period = period or max(n_ticks, 2)
    out: list[Request] = []
    burst_left = 0
    for t in range(n_ticks):
        envelope = 0.25 + 0.75 * (1 + np.sin(2 * np.pi * t / period)) / 2
        if burst_left == 0 and rng.random() < burst_prob:
            burst_left = burst_len
        lam = rate * envelope * (burst if burst_left > 0 else 1.0)
        burst_left = max(burst_left - 1, 0)
        for _ in range(rng.poisson(lam)):
            name, blocks, decode, _w = mix[rng.choice(len(mix), p=p)]
            out.append(Request(t, blocks, decode, name))
    return out


# --------------------------------------------------------- the harness

def _count(name: str, n: int, scope: str | None) -> None:
    """Scoped registry bump + base-name trace count record.

    The registry is process-global, so the metric name carries the run
    scope (``engine.scoped``); the TRACE record keeps the declared base
    name — per-run isolation there comes from span parentage (the
    record's parent chain roots at this run's ``serve.load_run`` span),
    which is how ``serve_record`` sums counts per run subtree even with
    several runs in one trace file."""
    if n:
        obs.REGISTRY.inc(engine.scoped(name, scope), n)
        obs.tracer().count(name, n)


def run_load(requests: list[Request],
             cfg: engine.EngineConfig | None = None,
             lanes: int = 1,
             run: str = "serve",
             arrival: str = "poisson",
             rate: float = 0.0,
             drain_ticks: int = 512,
             scope: str | None = None) -> dict:
    """Replay an arrival trace through ``lanes`` sharded engines.

    Arrivals are assigned to lanes round-robin; within a lane the host
    scheduler keeps a FIFO queue, maps queued requests onto free slots,
    and drives one fused jitted device step per tick:

        admit_where → decode_translate → retire_where

    over the whole ``[lanes, ...]`` engine state on the ``("lane",)``
    mesh.  Admissions the engine rejects (page pool exhausted — the
    aliasing bugfix surfaced as backpressure) re-queue at the back and
    count into ``serve.pool_exhausted``.  After the last arrival the
    loop drains in-flight work for at most ``drain_ticks`` extra ticks.

    Returns the derived BENCH_serve record (also appended to
    :data:`SERVE_PERF`).
    """
    cfg = cfg or engine.EngineConfig()
    scope = scope or run
    gate = (cfg.gate_freq_min, cfg.gate_cost_min)
    n_slots = cfg.n_slots
    tr = obs.tracer()

    st = jax.tree.map(lambda x: jnp.stack([x] * lanes), engine.init(cfg))

    def lane_step(s, admit_blocks, targets):
        s, oks = engine.admit_where(s, admit_blocks)
        s, _phys, _src = engine.decode_translate(s, cfg)
        ret = s.slot_live & (targets > 0) & (s.slot_len >= targets)
        s, n_inval = engine.retire_where(s, ret)
        return s, oks, ret, n_inval

    step = parallel.shard_lanes(jax.vmap(lane_step), lanes)

    # warm the jit cache OUTSIDE the run span (state is functional, the
    # no-op output is discarded) so the p99 tail reflects steady-state
    # decode latency, not the one-time XLA compile
    zeros = jnp.zeros((lanes, n_slots), jnp.int32)
    jax.block_until_ready(step(st, zeros, zeros))

    # host-side scheduler mirrors (updated from fetched step outputs)
    queues = [collections.deque() for _ in range(lanes)]
    free_slots = [set(range(n_slots)) for _ in range(lanes)]
    inflight: list[list] = [[None] * n_slots for _ in range(lanes)]
    targets_h = np.zeros((lanes, n_slots), np.int32)

    by_tick: dict[int, list] = {}
    for i, r in enumerate(requests):
        by_tick.setdefault(r.arrive_tick, []).append((i % lanes, r))
    last_tick = max((r.arrive_tick for r in requests), default=0)
    n_arr = len(requests)
    done = 0
    t = 0

    with obs.span(names.SPAN_SERVE_RUN, run=run, arrival=arrival,
                  rate=rate, lanes=lanes, mesh=step.mesh_dim,
                  devices=jax.local_device_count(), n_slots=n_slots,
                  n_pool_pages=cfg.n_pool_pages,
                  gate=list(gate)) as run_span:
        while t <= last_tick or (done < n_arr and
                                 t <= last_tick + drain_ticks):
            for lane, r in by_tick.get(t, ()):
                queues[lane].append((r, t))
            admit_blocks = np.zeros((lanes, n_slots), np.int32)
            attempt: list[list] = [[None] * n_slots for _ in range(lanes)]
            for ln in range(lanes):
                while queues[ln] and free_slots[ln]:
                    slot = min(free_slots[ln])       # deterministic pick
                    free_slots[ln].remove(slot)
                    req, at = queues[ln].popleft()
                    attempt[ln][slot] = (req, at)
                    admit_blocks[ln, slot] = req.prompt_blocks
                    targets_h[ln, slot] = (
                        req.prompt_blocks * btab.TOKENS_PER_PAGE
                        + req.decode_tokens)

            with obs.span(names.SPAN_DECODE_STEP):
                t0 = time.perf_counter()
                st, oks, rets, n_inval = step(
                    st, jnp.asarray(admit_blocks), jnp.asarray(targets_h))
                jax.block_until_ready(st)
                obs.observe(engine.scoped(names.HIST_DECODE_STEP_S, scope),
                            time.perf_counter() - t0)
            obs.REGISTRY.inc(engine.scoped(names.CTR_DECODE_STEPS, scope))

            oks_h = np.asarray(jax.device_get(oks))
            rets_h = np.asarray(jax.device_get(rets))
            n_adm = n_rej = n_ret = 0
            for ln in range(lanes):
                for sl in range(n_slots):
                    a = attempt[ln][sl]
                    if a is not None:
                        if oks_h[ln, sl]:
                            inflight[ln][sl] = a
                            n_adm += 1
                        else:
                            # pool exhausted: nothing was allocated —
                            # re-queue at the back, slot stays free
                            queues[ln].append(a)
                            free_slots[ln].add(sl)
                            targets_h[ln, sl] = 0
                            n_rej += 1
                    if rets_h[ln, sl]:
                        req, at = inflight[ln][sl]
                        inflight[ln][sl] = None
                        free_slots[ln].add(sl)
                        targets_h[ln, sl] = 0
                        obs.observe(
                            engine.scoped(names.HIST_REQ_TICKS, scope),
                            t - at + 1)
                        n_ret += 1
                        done += 1
            _count(names.CTR_REQS_ADMITTED, n_adm, scope)
            _count(names.CTR_POOL_EXHAUSTED, n_rej, scope)
            _count(names.CTR_REQS_RETIRED, n_ret, scope)
            _count(names.CTR_VTC_INVALIDATE,
                   int(np.sum(np.asarray(jax.device_get(n_inval)))), scope)
            t += 1

        # run-level attrs the record derives via `attr` sources: summed
        # over lanes from the FINAL device state (fetched, host ints)
        st_h = jax.device_get(st)
        hit_tc = int(np.sum(np.asarray(st_h.vtc.n_hit_tc)))
        hit_cl = int(np.sum(np.asarray(st_h.vtc.n_hit_cluster)))
        walks = int(np.sum(np.asarray(st_h.vtc.n_walk)))
        pool_stall = int(np.sum(np.asarray(st_h.n_pool_stall)))
        run_span.set(n_ticks=t, n_arrivals=n_arr, pool_stall=pool_stall,
                     vtc_hit_tc=hit_tc, vtc_hit_cluster=hit_cl,
                     vtc_walk=walks)
        obs.REGISTRY.inc_to(
            engine.scoped(names.CTR_VTC_HIT_TC, scope), hit_tc)
        obs.REGISTRY.inc_to(
            engine.scoped(names.CTR_VTC_HIT_CLUSTER, scope), hit_cl)
        obs.REGISTRY.inc_to(
            engine.scoped(names.CTR_VTC_WALK, scope), walks)
        obs.REGISTRY.inc_to(
            engine.scoped(names.CTR_POOL_EXHAUSTED, scope), pool_stall)

    rec = obs.report.serve_record(tr.events, run_span.id, tr.path)
    SERVE_PERF.append(rec)
    return rec


# ----------------------------------------------------- PTW-CP gate tuning

def tune_gate(workloads=("bc", "xs"), n: int = 20_000) -> tuple[int, int]:
    """Tune the engine's cluster-install gate from the simulator's PTW-CP.

    Runs the sweep engine's collect-mode radix system over ``workloads``,
    refits the paper's comparator box on the collected (freq, cost)
    features (``ptwcp_nn.fit_box``, exhaustive F1 search — the same refit
    Table 2 reports), and maps the box's LOWER edges onto the serving
    gate ``(gate_freq_min, gate_cost_min)``.  Only the lower edges
    transfer: the engine's per-leaf-row counters are lifetime-saturating
    (see ``translation_cache.translate``), so the box's upper bounds
    would permanently exclude every hot row once its counter saturates.
    """
    from repro.core import ptwcp_nn
    from repro.sim import runner
    out = runner.run_batch("radix_collect", workloads=list(workloads), n=n)
    X, y = ptwcp_nn.build_dataset([out[w][1] for w in workloads])
    clo, _chi, flo, _fhi = ptwcp_nn.fit_box(X, y)
    return (min(int(flo), 7), min(int(clo), 15))
