"""Pallas TPU flash attention (train/prefill hot-spot).

Grid (B, H, nq, nk) with online-softmax accumulation in VMEM scratch; the
GQA mapping happens in the K/V BlockSpec index maps (head h reads kv head
h // group), so KV is never materialized per-head.  Causal blocks that are
fully masked are skipped via ``pl.when`` on the block indices.

TARGET: TPU (MXU 128×128 tiles).  VALIDATED: interpret=True on CPU against
``ref.mha_reference`` (tests/test_kernels_flash.py sweeps shapes/dtypes).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128
NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale: float, causal: bool, block_q: int, block_k: int,
            nk: int, window: int | None):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = qi * block_q
    k_start = ki * block_k

    # skip fully-masked blocks (strictly above the causal diagonal /
    # outside the window)
    run = jnp.bool_(True)
    if causal:
        run = run & (k_start <= q_start + block_q - 1)
    if window is not None:
        run = run & (k_start + block_k - 1 >= q_start - window + 1)

    @pl.when(run)
    def _compute():
        q = q_ref[0, 0, ...].astype(jnp.float32)       # [bq, hd]
        k = k_ref[0, 0, ...].astype(jnp.float32)       # [bk, hd]
        v = v_ref[0, 0, ...].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # [bq, bk]
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = jnp.ones_like(s, jnp.bool_)
        if causal:
            mask = mask & (qpos >= kpos)
        if window is not None:
            mask = mask & (qpos - kpos < window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]                            # [bq, 1]
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        o_ref[0, 0, ...] = (acc_ref[...]
                         / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "block_q", "block_k", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int | None = None,
                    block_q: int = DEFAULT_BLOCK_Q,
                    block_k: int = DEFAULT_BLOCK_K,
                    interpret: bool = False):
    """q [B,H,S,hd]; k,v [B,K,S,hd] with H % K == 0. Returns [B,H,S,hd]."""
    B, H, S, hd = q.shape
    K = k.shape[1]
    G = H // K
    Sk = k.shape[2]
    block_q = min(block_q, S)
    block_k = min(block_k, Sk)
    assert S % block_q == 0 and Sk % block_k == 0
    nq, nk = S // block_q, Sk // block_k
    scale = 1.0 / (hd ** 0.5)

    kernel = functools.partial(
        _kernel, scale=scale, causal=causal, block_q=block_q,
        block_k=block_k, nk=nk, window=window)

    return pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, hd),
                         lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda b, h, qi, ki: (b, h // G, ki, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda b, h, qi, ki: (b, h // G, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, hd),
                               lambda b, h, qi, ki: (b, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, S, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),   # running max
            pltpu.VMEM((block_q, 1), jnp.float32),   # running denom
            pltpu.VMEM((block_q, hd), jnp.float32),  # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
