"""Pure-jnp oracles for every Pallas kernel (fp32 math, no tiling).

Tests sweep shapes/dtypes and assert_allclose kernels (interpret=True)
against these references.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def mha_reference(q, k, v, *, causal=True, window=None):
    """q [B,H,S,hd]; k,v [B,K,Sk,hd] (GQA). Returns [B,H,S,hd] fp32-exact."""
    B, H, S, hd = q.shape
    K = k.shape[1]
    G = H // K
    kf = jnp.repeat(k, G, axis=1).astype(jnp.float32)
    vf = jnp.repeat(v, G, axis=1).astype(jnp.float32)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), kf)
    s = s / (hd ** 0.5)
    qpos = jnp.arange(S)[:, None]
    kpos = jnp.arange(k.shape[2])[None, :]
    mask = jnp.ones((S, k.shape[2]), bool)
    if causal:
        mask = mask & (qpos >= kpos)
    if window is not None:
        mask = mask & (qpos - kpos < window)
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, vf).astype(q.dtype)


def paged_attention_reference(q, k_pages, v_pages, tables, lens):
    """q [B,H,hd]; pages [P,page,K,hd]; tables [B,nb]; lens [B]."""
    B, H, hd = q.shape
    P, page, K, _ = k_pages.shape
    G = H // K
    nb = tables.shape[1]
    # gather logical KV [B, nb*page, K, hd]
    k = k_pages[tables].reshape(B, nb * page, K, hd).astype(jnp.float32)
    v = v_pages[tables].reshape(B, nb * page, K, hd).astype(jnp.float32)
    kf = jnp.repeat(k, G, axis=2)
    vf = jnp.repeat(v, G, axis=2)
    s = jnp.einsum("bhd,bshd->bhs", q.astype(jnp.float32),
                   jnp.moveaxis(kf, 1, 1)) / (hd ** 0.5)
    tok = jnp.arange(nb * page)[None, None, :]
    s = jnp.where(tok < lens[:, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhs,bshd->bhd", p, vf)
    return out.astype(q.dtype)


def ssd_intra_reference(x, dt, dA, B, C):
    """Intra-chunk SSD block for ONE (batch, chunk, group, rep):
    x [q,p]; dt,dA [q]; B,C [q,n].  Returns (y [q,p], S_loc [n,p])."""
    q = x.shape[0]
    cs = jnp.cumsum(dA)
    CB = jnp.einsum("in,jn->ij", C.astype(jnp.float32),
                    B.astype(jnp.float32))
    L = jnp.exp(jnp.clip(cs[:, None] - cs[None, :], -60.0, 0.0))
    L = L * jnp.tril(jnp.ones((q, q)))
    W = CB * L * dt[None, :]
    y = W @ x.astype(jnp.float32)
    decay_end = jnp.exp(jnp.clip(cs[-1] - cs, -60.0, 0.0))
    S_loc = jnp.einsum("qn,q,qp->np", B.astype(jnp.float32),
                       decay_end * dt, x.astype(jnp.float32))
    return y.astype(x.dtype), S_loc.astype(x.dtype)
