"""jit'd public wrappers around the Pallas kernels.

On the CPU backend everything runs in interpret mode automatically (the
Mosaic TPU compiler is unavailable), so the same call sites work in tests,
examples, and on real TPUs.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as _fa
from repro.kernels import paged_attention as _pa
from repro.kernels import ssd_scan as _ssd

_INTERPRET = jax.default_backend() == "cpu"


def flash_attention(q, k, v, *, causal=True, window=None,
                    block_q=128, block_k=128, interpret=None):
    """q [B,S,H,hd]; k,v [B,Sk,K,hd] (model layout). Returns [B,S,H,hd]."""
    interpret = _INTERPRET if interpret is None else interpret
    qt = jnp.swapaxes(q, 1, 2)
    kt = jnp.swapaxes(k, 1, 2)
    vt = jnp.swapaxes(v, 1, 2)
    o = _fa.flash_attention(qt, kt, vt, causal=causal, window=window,
                            block_q=block_q, block_k=block_k,
                            interpret=interpret)
    return jnp.swapaxes(o, 1, 2)


def paged_attention(q, k_pages, v_pages, tables, lens, *, interpret=None):
    interpret = _INTERPRET if interpret is None else interpret
    return _pa.paged_attention(q, k_pages, v_pages, tables, lens,
                               interpret=interpret)


def ssd_intra(x, dt, dA, B, C, *, interpret=None):
    interpret = _INTERPRET if interpret is None else interpret
    return _ssd.ssd_intra(x, dt, dA, B, C, interpret=interpret)
