"""Pallas TPU kernel for the mamba2 SSD intra-chunk block.

Grid (b·nc, g, r): each invocation computes, for one chunk × head,
  y_intra = (C·Bᵀ ⊙ exp(cs_i - cs_j) ⊙ tril ⊙ dt_j) @ x      [q, p]
  S_loc   = (B ⊙ (exp(cs_end - cs)·dt))ᵀ @ x                  [n, p]
with q = chunk = 128, n = state = 128 → all three contractions are
128×128 MXU tiles.  The inter-chunk prefix recurrence stays in XLA
(associative_scan) — it is O(s/q) and latency-, not compute-bound.

TARGET: TPU.  VALIDATED: interpret=True vs ``ref.ssd_intra_reference``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, dt_ref, da_ref, b_ref, c_ref, y_ref, s_ref, *, q: int):
    x = x_ref[0, :, 0, :].astype(jnp.float32)        # [q, p]
    dt = dt_ref[0, :, 0, :].astype(jnp.float32)      # [q, 1]
    dA = da_ref[0, :, 0, :].astype(jnp.float32)      # [q, 1]
    Bm = b_ref[0, :, 0, :].astype(jnp.float32)       # [q, n]
    Cm = c_ref[0, :, 0, :].astype(jnp.float32)       # [q, n]

    cs = jnp.cumsum(dA[:, 0])                        # [q]
    CB = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # [q,q]
    diff = cs[:, None] - cs[None, :]
    L = jnp.exp(jnp.clip(diff, -60.0, 0.0))
    ii = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    L = jnp.where(ii >= jj, L, 0.0)
    W = CB * L * dt[:, 0][None, :]
    y = jax.lax.dot_general(W, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    y_ref[0, :, 0, :] = y.astype(y_ref.dtype)

    decay_end = jnp.exp(jnp.clip(cs[-1] - cs, -60.0, 0.0)) * dt[:, 0]
    Bw = Bm * decay_end[:, None]                     # [q, n]
    S = jax.lax.dot_general(Bw, x, (((0,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)  # [n, p]
    s_ref[0, 0, :, :] = S.astype(s_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def ssd_intra(x, dt, dA, B, C, *, interpret: bool = False):
    """x [T,q,R,p]; dt,dA [T,q,R,1]; B,C [T,q,R,n] where T = b·nc flattened
    chunks and R = g·r flattened heads.  Returns (y [T,q,R,p],
    S_loc [T,R,n,p])."""
    T, q, R, p = x.shape
    n = B.shape[-1]
    kernel = functools.partial(_kernel, q=q)
    y, S = pl.pallas_call(
        kernel,
        grid=(T, R),
        in_specs=[
            pl.BlockSpec((1, q, 1, p), lambda t, h: (t, 0, h, 0)),
            pl.BlockSpec((1, q, 1, 1), lambda t, h: (t, 0, h, 0)),
            pl.BlockSpec((1, q, 1, 1), lambda t, h: (t, 0, h, 0)),
            pl.BlockSpec((1, q, 1, n), lambda t, h: (t, 0, h, 0)),
            pl.BlockSpec((1, q, 1, n), lambda t, h: (t, 0, h, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, q, 1, p), lambda t, h: (t, 0, h, 0)),
            pl.BlockSpec((1, 1, n, p), lambda t, h: (t, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((T, q, R, p), x.dtype),
            jax.ShapeDtypeStruct((T, R, n, p), x.dtype),
        ],
        interpret=interpret,
    )(x, dt, dA, B, C)
    return y, S
