"""Pallas TPU paged-attention decode kernel.

The block table (post-VTC translation: logical block → physical KV page)
is a *scalar-prefetch* operand: it is staged into SMEM before the grid
runs and indexed inside the BlockSpec index maps — the physical page
gather happens in the kernel's DMA pipeline, never materializing a
contiguous KV copy in HBM.  This is the TPU embodiment of a "TLB hit":
translation metadata rides in scalar memory while payload pages stream
through VMEM (DESIGN.md §2.2).

Grid (B, K, nb): per request × kv-head × logical block, online softmax
over pages, GQA handled by a [G, hd] query tile per kv head.

TARGET: TPU.  VALIDATED: interpret=True vs ``ref.paged_attention_reference``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(tables, lens,           # scalar-prefetch operands (SMEM)
            q_ref, k_ref, v_ref, o_ref,
            m_ref, l_ref, acc_ref, *,
            page: int, nb: int):
    b = pl.program_id(0)
    i = pl.program_id(2)

    @pl.when(i == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    ctx = lens[b]
    base = i * page
    live = base < ctx  # any token of this block in context?

    @pl.when(live)
    def _compute():
        q = q_ref[0, ...].astype(jnp.float32)            # [G, hd]
        k = k_ref[0, :, 0, :].astype(jnp.float32)        # [page, hd]
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)          # [G, page]
        s = s * (1.0 / (q.shape[-1] ** 0.5))
        tok = base + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(tok < ctx, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(i == nb - 1)
    def _fin():
        o_ref[0, ...] = (acc_ref[...]
                         / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_attention(q, k_pages, v_pages, tables, lens, *,
                    interpret: bool = False):
    """q [B,H,hd]; k_pages/v_pages [P, page, K, hd]; tables [B, nb] int32
    physical page ids; lens [B] context lengths.  Returns [B,H,hd]."""
    B, H, hd = q.shape
    P, page, K, _ = k_pages.shape
    G = H // K
    nb = tables.shape[1]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, K, nb),
        in_specs=[
            pl.BlockSpec((1, G, hd),
                         lambda b, kh, i, tables, lens: (b, kh, 0)),
            pl.BlockSpec((1, page, 1, hd),
                         lambda b, kh, i, tables, lens:
                         (tables[b, i], 0, kh, 0)),
            pl.BlockSpec((1, page, 1, hd),
                         lambda b, kh, i, tables, lens:
                         (tables[b, i], 0, kh, 0)),
        ],
        out_specs=pl.BlockSpec((1, G, hd),
                               lambda b, kh, i, tables, lens: (b, kh, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, hd), jnp.float32),
        ],
    )
    kernel = functools.partial(_kernel, page=page, nb=nb)
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, H, hd), q.dtype),
        interpret=interpret,
    )(tables, lens, q, k_pages, v_pages)
    return out
