"""Pallas-fused blocked access scan for the MMU translation pipeline.

``jax.lax.scan`` threads the FULL ``MMUState`` carry through every
access: each step's gather/scatter-heavy assoc probes force XLA to
materialize the whole carry pytree per iteration, so the hot sweep loop
is dominated by carry traffic, not by translation math.  This kernel
restructures the scan into a grid of trace *blocks*:

  - the state pytree lives in kernel-resident buffers (VMEM on TPU) with
    a constant ``index_map``, so it persists ACROSS grid steps and is
    written back to HBM once, at the end — only the per-block trace
    slices stream in;
  - each grid step runs the unmodified per-access ``step`` over its
    block with an inner ``lax.scan`` whose carry never leaves the
    kernel, and folds the ``Stats`` deltas into the resident state.

The step function is the SAME traced composition ``mmu.make_step``
builds for the scan backend, so the two backends are bit-identical by
construction (pinned by tests/test_mmu_kernel.py on the full native and
virt ladder families).

TARGET: TPU.  On CPU the kernel runs in interpret mode (the Mosaic
compiler is unavailable), which preserves bit-identity but not the
carry-residency speedup — CI uses it as a correctness harness, real
wall-time wins need a TPU/GPU host.  Block sizes are auto-tuned: see
``pick_block``.
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

import repro.obs as obs

def _interpret_default() -> bool:
    # computed lazily, NOT at import time: querying the backend here
    # would initialize jax before sweep.py's --devices flag can set
    # --xla_force_host_platform_device_count
    return jax.default_backend() != "tpu"

# target grid length for auto-tuned blocks: enough blocks that the
# resident state demonstrably survives grid steps, few enough that
# interpret-mode CI (which pays per-grid-step kernel overhead) and the
# Mosaic unroll both stay cheap.  REPRO_PALLAS_BLOCK pins an explicit
# block-size target instead (pick_block still snaps it to a divisor).
TARGET_GRID = 8
_BLOCK_ENV = "REPRO_PALLAS_BLOCK"


def _divisors(n: int) -> list[int]:
    out = set()
    d = 1
    while d * d <= n:
        if n % d == 0:
            out.add(d)
            out.add(n // d)
        d += 1
    return sorted(out)


def pick_block(n: int, target: int | None = None) -> int:
    """Auto-tune the trace block size for an ``n``-access scan.

    The block must divide ``n`` exactly (padding the time axis would
    simulate phantom accesses and break bit-identity).  With no target,
    pick the divisor whose grid length is closest to ``TARGET_GRID`` —
    the measured compile-cost sweet spot: more blocks shrink the
    per-block working set but grow the (interpret-mode) per-step
    overhead and the kernel's compile time roughly linearly.  An
    explicit ``target`` (the ``REPRO_PALLAS_BLOCK`` env knob) snaps to
    the nearest divisor instead.  Ties prefer the LARGER block (fewer
    grid steps).  A prime ``n`` degenerates to one whole-trace block —
    still correct, just no blocking.
    """
    if n <= 0:
        raise ValueError(f"cannot block an empty trace (n={n})")
    if target is None:
        env = os.environ.get(_BLOCK_ENV, "").strip()
        target = int(env) if env else None
    divs = _divisors(n)
    if target is None:
        return min(divs, key=lambda d: (abs(n // d - TARGET_GRID), -d))
    if target < 1:
        raise ValueError(f"block target must be >= 1, got {target}")
    return min(divs, key=lambda d: (abs(d - target), -d))


def _r1(x):
    """Kernel refs want rank >= 1: scalar leaves ride as (1,) views."""
    return x.reshape((1,)) if x.ndim == 0 else x


def _full_spec(shape):
    nd = len(shape)
    return pl.BlockSpec(shape, lambda i, _nd=nd: (0,) * _nd)


@functools.partial(jax.jit,
                   static_argnames=("step", "treedefs", "block",
                                    "interpret", "n_leaves"))
def _blocked_scan_impl(step, treedefs, block, interpret, n_leaves,
                       tr_leaves, st_leaves, const_leaves):
    st_def, tr_def, const_def = treedefs
    n_tr, n_st = n_leaves
    st_shapes = tuple(x.shape for x in st_leaves)
    const_shapes = tuple(x.shape for x in const_leaves)
    ins = [_r1(x) for x in st_leaves]
    cins = [_r1(x) for x in const_leaves]
    n = tr_leaves[0].shape[0]

    def kernel(*refs):
        tr_refs = refs[:n_tr]
        init_refs = refs[n_tr:n_tr + n_st]
        const_refs = refs[n_tr + n_st:-n_st]
        out_refs = refs[-n_st:]

        # grid step 0 seeds the resident state from the initial carry;
        # later steps keep accumulating into the same buffers
        @pl.when(pl.program_id(0) == 0)
        def _seed():
            for o, i in zip(out_refs, init_refs):
                o[...] = i[...]

        st = jax.tree.unflatten(
            st_def, [o[...].reshape(s)
                     for o, s in zip(out_refs, st_shapes)])
        tr = jax.tree.unflatten(tr_def, [r[...] for r in tr_refs])
        if const_def is not None:
            consts = jax.tree.unflatten(
                const_def, [r[...].reshape(s)
                            for r, s in zip(const_refs, const_shapes)])
            body = lambda ss, acc: step(ss, acc, consts)  # noqa: E731
        else:
            body = step
        st, _ = jax.lax.scan(body, st, tr)
        for o, leaf in zip(out_refs, jax.tree.leaves(st)):
            o[...] = leaf.reshape(o.shape)

    def _tr_spec(x):
        nd = x.ndim
        return pl.BlockSpec((block,) + x.shape[1:],
                            lambda i, _nd=nd: (i,) + (0,) * (_nd - 1))

    kwargs = {}
    if not interpret:
        # the grid is a sequential reduction over trace blocks — the
        # resident-state pattern requires in-order execution
        try:
            from jax.experimental.pallas import tpu as pltpu
            params = getattr(pltpu, "CompilerParams",
                             getattr(pltpu, "TPUCompilerParams", None))
            if params is not None:
                kwargs["compiler_params"] = params(
                    dimension_semantics=("arbitrary",))
        except ImportError:  # non-TPU compiled backends pick their own
            pass

    out = pl.pallas_call(
        kernel,
        grid=(n // block,),
        in_specs=([_tr_spec(x) for x in tr_leaves]
                  + [_full_spec(x.shape) for x in ins]
                  + [_full_spec(x.shape) for x in cins]),
        out_specs=[_full_spec(x.shape) for x in ins],
        out_shape=[jax.ShapeDtypeStruct(x.shape, x.dtype) for x in ins],
        interpret=interpret,
        **kwargs,
    )(*tr_leaves, *ins, *cins)
    return jax.tree.unflatten(
        st_def, [o.reshape(s) for o, s in zip(out, st_shapes)])


def blocked_scan(step, st0, trace, consts=None, block: int | None = None,
                 interpret: bool | None = None):
    """Scan ``step`` over ``trace`` (time axis 0) in resident-state blocks.

    Drop-in for ``lax.scan(step, st0, trace)[0]`` (per-step outputs are
    discarded — the sweep folds everything into ``Stats`` inside the
    carry).  ``step(state, access[, consts]) -> (state, _)`` may be any
    traced function, including a workload/system-vmapped composition;
    ``consts`` is an optional pytree of per-call constants (e.g. the
    ladder's stacked ``Dyn`` scalars) delivered to the kernel as inputs
    — pallas kernels cannot close over traced arrays.  ``block``
    overrides the auto-tuned trace block size (``pick_block``);
    ``interpret`` defaults to interpreter mode off-TPU.
    """
    interpret = _interpret_default() if interpret is None else interpret

    # the stage composition bakes config-derived scalars into its
    # closure; a pallas kernel cannot capture constants, so the step is
    # traced to a jaxpr here and its captured consts hoisted into
    # explicit inputs that ride along with the caller's consts pytree
    # (jax.closure_convert only hoists tracers, not concrete arrays)
    ex_acc = jax.tree.map(lambda x: x[0], trace)

    def _stepc(st, acc, cst):
        return step(st, acc) if consts is None else step(st, acc, cst)

    closed, out_shape = jax.make_jaxpr(_stepc, return_shape=True)(
        st0, ex_acc, consts)
    out_def = jax.tree.structure(out_shape)
    hoisted = tuple(jnp.asarray(c) for c in closed.consts)

    def step_k(st, acc, ca):
        cst, hs = ca
        flat = jax.core.eval_jaxpr(closed.jaxpr, hs,
                                   *jax.tree.leaves((st, acc, cst)))
        return jax.tree.unflatten(out_def, flat)

    consts_all = (consts, tuple(hoisted))
    st_leaves, st_def = jax.tree.flatten(st0)
    tr_leaves, tr_def = jax.tree.flatten(trace)
    const_leaves, const_def = jax.tree.flatten(consts_all)
    n = tr_leaves[0].shape[0]
    blk = pick_block(n, block)
    # trace-time telemetry (static Python ints only — safe under any
    # transform): one event per kernel BUILD, i.e. per lowering, not per
    # execution, which is exactly the compile-cost signal TPU phase-2
    # block tuning needs
    obs.event(obs.names.EV_PALLAS_KERNEL, n=n, block=blk,
              grid=n // blk, interpret=bool(interpret))
    return _blocked_scan_impl(
        step_k, (st_def, tr_def, const_def), blk, interpret,
        (len(tr_leaves), len(st_leaves)),
        tuple(tr_leaves), tuple(st_leaves), tuple(const_leaves))
