"""AdamW + global-norm clipping + cosine schedule (pure pytree ops).

Moments are fp32 regardless of param dtype (mixed-precision training:
bf16 params, fp32 optimizer state).  No optax dependency — the optimizer
is part of the substrate per the reproduction mandate.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


class OptState(NamedTuple):
    step: jax.Array
    mu: object   # pytree, fp32
    nu: object   # pytree, fp32


def init(params) -> OptState:
    z = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return OptState(step=jnp.int32(0), mu=z,
                    nu=jax.tree.map(jnp.copy, z))


def schedule(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / max(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps)
                 / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * t))
    frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def update(cfg: AdamWConfig, grads, state: OptState, params):
    """Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    step = state.step + 1
    lr = schedule(cfg, step)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m2 = cfg.b1 * m + (1 - cfg.b1) * g
        v2 = cfg.b2 * v + (1 - cfg.b2) * g * g
        mhat = m2 / b1c
        vhat = v2 / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:  # decoupled decay on matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        p2 = p.astype(jnp.float32) - lr * delta
        return p2.astype(p.dtype), m2, v2

    out = jax.tree.map(upd, params, grads, state.mu, state.nu)
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    mu = jax.tree.map(lambda t: t[1], out,
                      is_leaf=lambda t: isinstance(t, tuple))
    nu = jax.tree.map(lambda t: t[2], out,
                      is_leaf=lambda t: isinstance(t, tuple))
    return new_params, OptState(step=step, mu=mu, nu=nu), {
        "grad_norm": gnorm, "lr": lr}
