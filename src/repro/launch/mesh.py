"""Production mesh construction.

Defined as functions (never module-level constants) so importing this
module never touches jax device state — required because the dry-run
launcher must set XLA_FLAGS before any jax initialization.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips/pod ('data','model'); 2 pods adds a leading 'pod'
    axis (DP by default, PP via dist.pp)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Tiny mesh over the real local devices (tests / examples)."""
    n = len(jax.devices())
    assert n % model == 0
    return jax.make_mesh((n // model, model), ("data", "model"))
