"""ShapeDtypeStruct input stand-ins for every (arch × shape) cell.

No device allocation ever happens here — everything is
ShapeDtypeStruct(+NamedSharding), the AOT-lowering pattern.  Modality
frontends are stubs per the assignment: audio/vision cells get
precomputed frame/patch embedding inputs.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.dist import sharding as shd


def _sds(shape, dtype, sharding=None):
    return jax.ShapeDtypeStruct(shape, dtype, sharding=sharding)


def train_batch_specs(cfg: ModelConfig, sc: ShapeConfig, mesh):
    B, S = sc.global_batch, sc.seq_len
    dp = shd.dp_axes(mesh)
    out = {"tokens": _sds((B, S), jnp.int32,
                          NamedSharding(mesh, P(dp, None)))}
    if cfg.family == "vlm":
        Pn = min(cfg.n_patches, S // 2)
        out["vision_embeds"] = _sds((B, Pn, cfg.d_model), jnp.bfloat16,
                                    NamedSharding(mesh, P(dp, None, None)))
        out["positions3"] = _sds((3, B, S), jnp.int32,
                                 NamedSharding(mesh, P(None, dp, None)))
    if cfg.family == "encdec":
        out["src_embeds"] = _sds((B, S, cfg.d_model), jnp.bfloat16,
                                 NamedSharding(mesh, P(dp, None, None)))
    return out


def param_specs(model, cfg: ModelConfig, mesh, mode: str = "fsdp"):
    shapes = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    shards = shd.param_shardings(mesh, shapes, mode)
    return jax.tree.map(
        lambda s, h: _sds(s.shape, s.dtype, h), shapes, shards)


def cache_specs(model, cfg: ModelConfig, sc: ShapeConfig, mesh):
    B, S = sc.global_batch, sc.seq_len
    shapes = jax.eval_shape(lambda: model.init_cache(B, S))
    shards = shd.cache_shardings(mesh, shapes, B)
    return jax.tree.map(
        lambda s, h: _sds(s.shape, s.dtype, h), shapes, shards)


def decode_input_specs(cfg: ModelConfig, sc: ShapeConfig, mesh):
    B = sc.global_batch
    dp = shd.dp_axes(mesh)
    bspec = P(dp) if B >= 16 else P()
    return {
        "tokens": _sds((B, 1), jnp.int32,
                       NamedSharding(mesh, P(dp, None) if B >= 16 else P())),
        "pos": _sds((B,), jnp.int32, NamedSharding(mesh, bspec)),
    }


def input_specs(model, cfg: ModelConfig, sc: ShapeConfig, mesh):
    """All lowering inputs for one cell, keyed by step-fn argument."""
    if sc.kind == "train":
        return {"batch": train_batch_specs(cfg, sc, mesh)}
    if sc.kind == "prefill":
        return {"batch": train_batch_specs(cfg, sc, mesh)}
    return {
        "cache": cache_specs(model, cfg, sc, mesh),
        **decode_input_specs(cfg, sc, mesh),
    }
