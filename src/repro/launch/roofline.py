"""Roofline analysis over dry-run artifacts (§Roofline deliverable).

Per (arch × shape), from the single-pod compiled dry-run:

  compute    = HLO_FLOPs / (chips · 197e12 FLOP/s)          [bf16 MXU]
  memory     = HLO_bytes / (chips · 819e9 B/s)              [HBM]
  collective = collective_bytes / (chips · 4 · 50e9 B/s)    [ICI, 4 links]

MODEL_FLOPS = 6·N·D (dense) or 6·N_active·D (MoE) per training step
(3·N·D fwd-only for prefill; 2·N_active per token for decode), and the
useful-compute ratio MODEL_FLOPS / HLO_FLOPs flags remat/redundancy waste.

    PYTHONPATH=src python -m repro.launch.roofline [--dir artifacts/dryrun]
"""
from __future__ import annotations

import argparse
import glob
import json
import os

from repro.configs import get_config
from repro.configs.base import SHAPES

CHIPS = 256              # single-pod roofline (16×16)
PEAK_FLOPS = 197e12      # TPU v5e bf16
HBM_BW = 819e9
ICI_BW_LINK = 50e9
ICI_LINKS = 4            # links/chip on a 2-D torus axis pair


def model_flops(arch: str, shape: str) -> float:
    cfg = get_config(arch)
    sc = SHAPES[shape]
    n_act = cfg.n_active_params()
    tokens = sc.global_batch * sc.seq_len
    if sc.kind == "train":
        return 6.0 * n_act * tokens
    if sc.kind == "prefill":
        return 2.0 * n_act * tokens  # fwd only
    # decode: one token per sequence + attention over the cache
    flops = 2.0 * n_act * sc.global_batch
    if cfg.family not in ("ssm",):
        hd = cfg.hd
        S = min(sc.seq_len, cfg.window) if cfg.window else sc.seq_len
        flops += (4.0 * cfg.n_heads * hd * S * cfg.n_layers
                  * sc.global_batch)
    return flops


def loop_scale(arch: str, shape: str) -> float:
    """XLA cost_analysis counts while-loop (scan-over-layers) bodies ONCE.
    Reconstruct full-step totals via the analytic ratio

        scale = model_flops(all L layers) / model_flops(one layer + out)

    where `out` (embedding/logits/optimizer) is outside the loop.  The
    measured HLO value then carries the real remat/redundancy overhead and
    the analytic ratio carries the trip count."""
    cfg = get_config(arch)
    sc = SHAPES[shape]
    tokens = sc.global_batch * sc.seq_len
    k = 6.0 if sc.kind == "train" else 2.0
    t_eff = tokens if sc.kind != "decode" else sc.global_batch
    emb = cfg.padded_vocab * cfg.d_model * (1 if cfg.tie_embeddings else 2)
    layer_par = max(cfg.n_active_params() - emb, 1)
    L = cfg.n_layers + cfg.n_enc_layers
    # logits are computed on every token in training but only the last
    # position for prefill / the single new token for decode
    t_logits = tokens if sc.kind == "train" else sc.global_batch
    out_flops = k * emb * t_logits
    full = k * layer_par * t_eff + out_flops
    once = k * (layer_par / max(L, 1)) * t_eff + out_flops
    return full / max(once, 1.0)


def analyze(rec: dict) -> dict:
    """cost_analysis() on SPMD modules is PER-DEVICE with loop bodies
    counted once; scale by the analytic trip-count ratio (see loop_scale)
    to get full-step per-device totals."""
    arch, shape = rec["arch"], rec["shape"]
    scale = loop_scale(arch, shape)
    flops_dev = rec["flops"] * scale
    bytes_dev = rec["bytes_accessed"] * scale
    coll_dev = rec["collective_bytes"]["total"] * scale
    t_comp = flops_dev / PEAK_FLOPS
    t_mem = bytes_dev / HBM_BW
    t_coll = coll_dev / (ICI_LINKS * ICI_BW_LINK)
    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    dom = max(terms, key=terms.get)
    mf = model_flops(arch, shape)
    useful = (mf / CHIPS) / max(flops_dev, 1.0)
    bound = max(terms.values())
    return {
        "arch": arch, "shape": shape, "loop_scale": scale,
        "t_compute_s": t_comp, "t_memory_s": t_mem, "t_collective_s": t_coll,
        "dominant": dom,
        "model_flops": mf,
        "useful_ratio": useful,
        "roofline_fraction": t_comp / max(bound, 1e-30),
        "per_device_bytes": (rec["memory"]["argument_size_bytes"]
                             + rec["memory"]["temp_size_bytes"]),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="/root/repo/artifacts/dryrun")
    ap.add_argument("--mesh", default="16x16")
    ap.add_argument("--out", default="/root/repo/artifacts/roofline.json")
    args = ap.parse_args()

    rows = []
    for path in sorted(glob.glob(os.path.join(args.dir, "*.json"))):
        with open(path) as f:
            rec = json.load(f)
        if rec.get("mesh") != args.mesh:
            continue
        if rec.get("status") != "ok":
            rows.append({"arch": rec["arch"], "shape": rec["shape"],
                         "status": rec.get("status")})
            continue
        rows.append(analyze(rec))

    hdr = (f"{'arch':<22s}{'shape':<13s}{'compute(s)':>11s}{'memory(s)':>11s}"
           f"{'coll(s)':>10s} {'dominant':<11s}{'useful':>7s}{'roofl%':>7s}")
    print(hdr)
    print("-" * len(hdr))
    for r in rows:
        if "dominant" not in r:
            print(f"{r['arch']:<22s}{r['shape']:<13s}  {r['status']}")
            continue
        print(f"{r['arch']:<22s}{r['shape']:<13s}"
              f"{r['t_compute_s']:>11.3e}{r['t_memory_s']:>11.3e}"
              f"{r['t_collective_s']:>10.2e} {r['dominant']:<11s}"
              f"{r['useful_ratio']:>7.2f}{r['roofline_fraction']*100:>6.0f}%")
    with open(args.out, "w") as f:
        json.dump(rows, f, indent=1)
    print(f"\nwritten {args.out}")


if __name__ == "__main__":
    main()
