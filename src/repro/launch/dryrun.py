import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

The two lines above MUST run before any other import — jax locks the
device count at first init.  Usage:

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-32b \
        --shape train_4k [--multi-pod] [--out artifacts/dryrun]
    PYTHONPATH=src python -m repro.launch.dryrun --sweep

Per cell this produces: compiled.memory_analysis(), cost_analysis(),
and collective-bytes parsed from the optimized HLO — the §Roofline inputs.
"""
import argparse  # noqa: E402
import json  # noqa: E402
import re  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402

jax.config.update("jax_compilation_cache_dir",
                  os.environ.get("JAX_CACHE", "/root/repo/.jax_cache"))
jax.config.update("jax_persistent_cache_min_compile_time_secs", 5)

import jax.numpy as jnp  # noqa: E402

from repro.configs import ARCHS, get_config  # noqa: E402
from repro.configs.base import SHAPES, cell_status  # noqa: E402
from repro.dist import sharding as shd  # noqa: E402
from repro.launch import specs as S  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models.model import build  # noqa: E402
from repro.optim import adamw  # noqa: E402
from repro.train.train_step import TrainConfig, TrainState, make_train_step  # noqa: E402

_COLL_RE = re.compile(
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"[^=]*=\s*\(?([a-z0-9]+)\[([0-9,]*)\]")
_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
    "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1,
}


def collective_bytes(hlo_text: str) -> dict:
    """Sum output-operand bytes of every collective op in optimized HLO."""
    out = {}
    for m in _COLL_RE.finditer(hlo_text):
        op, dt, dims = m.group(1), m.group(2), m.group(3)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        out[op] = out.get(op, 0) + n * _DTYPE_BYTES[dt]
    out["total"] = sum(v for k, v in out.items())
    return out


def build_step(arch: str, shape: str, mesh):
    cfg = get_config(arch)
    sc = SHAPES[shape]
    model = build(cfg, constrain=shd.make_constrain(mesh))
    specs = S.input_specs(model, cfg, sc, mesh)
    # decode placement: tp2d for batch==1 (§Perf B2), pure-TP for batched
    # decode (§Perf A5); train/prefill keep FSDP×TP
    pmode = "fsdp"
    if sc.kind == "decode" and os.environ.get("REPRO_DECODE_TP2D", "1") == "1" \
            and sc.global_batch == 1:
        pmode = "tp2d"  # pure-TP ('tp') for batched decode was REFUTED:
        #                 −10.7% coll but +17.6% bytes and 26 GB/dev temps
        #                 (> v5e HBM) on qwen3-32b — §Perf A5
    pspecs = S.param_specs(model, cfg, mesh, pmode)

    if sc.kind == "train":
        tcfg = TrainConfig()
        step = make_train_step(model, tcfg)
        # optimizer moments shard like their params
        mu = jax.tree.map(lambda p: jax.ShapeDtypeStruct(
            p.shape, jnp.float32, sharding=p.sharding), pspecs)
        state_specs = TrainState(
            params=pspecs,
            opt=adamw.OptState(step=jax.ShapeDtypeStruct((), jnp.int32),
                               mu=mu, nu=mu))

        def fn(state, batch):
            return step(state, batch)

        args = (state_specs, specs["batch"])
        donate = (0,)
    elif sc.kind == "prefill":
        model_local = model

        def fn(params, batch):
            return model_local.prefill(params, batch)

        args = (pspecs, specs["batch"])
        donate = ()
    else:  # decode
        def fn(params, cache, tokens, pos):
            return model.decode_step(params, cache, tokens, pos)

        args = (pspecs, specs["cache"], specs["tokens"], specs["pos"])
        donate = (1,)
        # pin the updated cache to its input sharding — otherwise GSPMD
        # may materialize a replicated cache on the way out (§Perf A2)
        out_shardings = (None,
                         jax.tree.map(lambda s: s.sharding, specs["cache"]))
        return fn, args, donate, out_shardings

    return fn, args, donate, None


def run_cell(arch: str, shape: str, multi_pod: bool, outdir: str):
    status = cell_status(arch, shape)
    meshname = "2x16x16" if multi_pod else "16x16"
    tag = f"{arch}__{shape}__{meshname}"
    os.makedirs(outdir, exist_ok=True)
    path = os.path.join(outdir, tag + ".json")
    if status != "run":
        rec = {"arch": arch, "shape": shape, "mesh": meshname,
               "status": status}
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
        print(f"[dryrun] {tag}: {status}")
        return rec

    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    with mesh:
        fn, args, donate, out_sh = build_step(arch, shape, mesh)
        kw = {"out_shardings": out_sh} if out_sh is not None else {}
        lowered = jax.jit(fn, donate_argnums=donate, **kw).lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        hlo = compiled.as_text()
        coll = collective_bytes(hlo)

    rec = {
        "arch": arch, "shape": shape, "mesh": meshname, "status": "ok",
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "flops": cost.get("flops", 0.0),
        "bytes_accessed": cost.get("bytes accessed", 0.0),
        "collective_bytes": coll,
        "memory": {
            "argument_size_bytes": getattr(mem, "argument_size_in_bytes", 0),
            "output_size_bytes": getattr(mem, "output_size_in_bytes", 0),
            "temp_size_bytes": getattr(mem, "temp_size_in_bytes", 0),
            "generated_code_size_bytes":
                getattr(mem, "generated_code_size_in_bytes", 0),
        },
    }
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    print(f"[dryrun] {tag}: ok  lower={t_lower:.0f}s compile={t_compile:.0f}s"
          f" flops={rec['flops']:.3g} coll={coll['total']:.3g}B")
    print("  memory_analysis:", rec["memory"])
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--sweep", action="store_true",
                    help="all (arch × shape) cells on this mesh")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="/root/repo/artifacts/dryrun")
    args = ap.parse_args()

    cells = []
    if args.sweep:
        for a in ARCHS:
            for s in SHAPES:
                cells.append((a, s))
    else:
        cells.append((args.arch, args.shape))

    meshes = [args.multi_pod]
    if args.both_meshes:
        meshes = [False, True]

    failures = []
    for mp in meshes:
        for a, s in cells:
            try:
                run_cell(a, s, mp, args.out)
            except Exception as e:  # noqa: BLE001
                failures.append((a, s, mp, repr(e)))
                traceback.print_exc()
    if failures:
        print("FAILURES:")
        for f in failures:
            print(" ", f)
        raise SystemExit(1)
    print("dry-run complete: all cells ok")


if __name__ == "__main__":
    main()
