"""Fault-tolerant training loop.

Production posture (designed for 1000+ nodes, exercised here at small
scale + in tests):

  * step-atomic async checkpoints every ``ckpt_every`` steps (crash at any
    point resumes from the last committed step; the data pipeline replays
    deterministically from that step),
  * failure handling — any exception in the step (preemption, device loss,
    injected fault) triggers restore-from-latest + replay; bounded retries,
  * straggler mitigation — per-step deadline watchdog: a step exceeding
    ``straggler_factor ×`` the rolling median latency is logged and
    counted (on real multi-host deployments this signal feeds the
    coordinator's replace-node decision; here it drives tests),
  * elastic restart — ``resume(mesh)`` re-places the checkpoint onto a
    different mesh via CheckpointManager.restore_resharded.
"""
from __future__ import annotations

import dataclasses
import statistics
import time
from typing import Callable, Optional

import jax

from repro.ckpt.checkpoint import CheckpointManager
from repro.train.train_step import TrainState


@dataclasses.dataclass
class LoopConfig:
    total_steps: int = 100
    ckpt_every: int = 20
    ckpt_dir: str = "/tmp/repro_ckpt"
    keep_n: int = 3
    max_retries: int = 3
    straggler_factor: float = 3.0
    log_every: int = 10


class Trainer:
    def __init__(self, step_fn: Callable, batch_fn: Callable,
                 cfg: LoopConfig, fault_hook: Optional[Callable] = None):
        """step_fn(state, batch)->(state, metrics); batch_fn(step)->batch;
        fault_hook(step) may raise to inject failures (tests)."""
        self.step_fn = step_fn
        self.batch_fn = batch_fn
        self.cfg = cfg
        self.fault_hook = fault_hook
        self.ckpt = CheckpointManager(cfg.ckpt_dir, keep_n=cfg.keep_n)
        self.step_times: list[float] = []
        self.n_stragglers = 0
        self.n_restarts = 0

    def resume_or_init(self, init_state: TrainState):
        state, step = self.ckpt.restore(init_state)
        if state is None:
            return init_state, 0
        return state, step

    def run(self, state: TrainState, start_step: int = 0):
        cfg = self.cfg
        step = start_step
        retries = 0
        history = []
        while step < cfg.total_steps:
            try:
                if self.fault_hook is not None:
                    self.fault_hook(step)
                t0 = time.time()
                batch = self.batch_fn(step)
                state, metrics = self.step_fn(state, batch)
                jax.block_until_ready(metrics["loss"])
                dt = time.time() - t0
                self.step_times.append(dt)
                if len(self.step_times) >= 5:
                    med = statistics.median(self.step_times[-20:])
                    if dt > cfg.straggler_factor * med:
                        self.n_stragglers += 1
                history.append(float(metrics["loss"]))
                if step % cfg.log_every == 0:
                    print(f"[train] step {step:5d} loss "
                          f"{float(metrics['loss']):.4f} "
                          f"({dt*1e3:.0f} ms)", flush=True)
                step += 1
                retries = 0
                if step % cfg.ckpt_every == 0:
                    self.ckpt.save(step, state)
            except KeyboardInterrupt:
                raise
            except Exception as e:  # noqa: BLE001 — fault boundary
                retries += 1
                self.n_restarts += 1
                print(f"[train] FAULT at step {step}: {e!r} — "
                      f"restoring (retry {retries}/{cfg.max_retries})",
                      flush=True)
                if retries > cfg.max_retries:
                    raise
                self.ckpt.wait()
                restored, rstep = self.ckpt.restore(state)
                if restored is not None:
                    state, step = restored, rstep
                # else: replay from the initial state
        self.ckpt.wait()
        self.ckpt.save(step, state, block=True)
        return state, history
