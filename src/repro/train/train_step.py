"""Training step: loss → grads → (optionally compressed) update.

Microbatch gradient accumulation happens via an inner scan when
``accum_steps > 1`` (keeps peak activation memory ∝ microbatch).
Cross-pod gradient compression (error-feedback int8) hooks in through
``repro.dist.compress`` when enabled.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.optim import adamw


class TrainState(NamedTuple):
    params: object
    opt: adamw.OptState


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    opt: adamw.AdamWConfig = adamw.AdamWConfig()
    accum_steps: int = 1
    compress_pod_grads: bool = False  # EF-int8 across the 'pod' axis


def init_state(params) -> TrainState:
    return TrainState(params=params, opt=adamw.init(params))


def make_train_step(model, tcfg: TrainConfig, compress_fn=None):
    """Returns train_step(state, batch) -> (state, metrics)."""

    def grads_of(params, batch):
        return jax.value_and_grad(lambda p: model.loss(p, batch))(params)

    def train_step(state: TrainState, batch):
        if tcfg.accum_steps > 1:
            a = tcfg.accum_steps

            def reshape(x):
                return x.reshape((a, x.shape[0] // a) + x.shape[1:])

            mb = jax.tree.map(reshape, batch)

            def body(carry, micro):
                loss_acc, g_acc = carry
                loss, g = grads_of(state.params, micro)
                g_acc = jax.tree.map(lambda A, B: A + B, g_acc, g)
                return (loss_acc + loss, g_acc), ()

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              state.params)
            (loss, grads), _ = jax.lax.scan(body, (jnp.float32(0), g0), mb)
            loss = loss / a
            grads = jax.tree.map(lambda g: g / a, grads)
        else:
            loss, grads = grads_of(state.params, batch)

        if compress_fn is not None:
            grads = compress_fn(grads)

        params, opt, om = adamw.update(tcfg.opt, grads, state.opt,
                                       state.params)
        metrics = {"loss": loss, **om}
        return TrainState(params=params, opt=opt), metrics

    return train_step
