"""Deterministic, shardable token data pipeline.

Sources: synthetic LM stream (seeded zipf-ish token model — always
available offline) or a binary token file (np.memmap).  Each *data-shard*
(host) draws disjoint slices by (shard_id, num_shards); batches are
reproducible functions of (seed, step) so restart-from-checkpoint replays
the exact stream — the property fault-tolerant training needs.  A
bounded prefetch thread hides generation latency.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Iterator, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    batch: int              # per-shard batch
    seq_len: int
    seed: int = 0
    path: Optional[str] = None   # token file (int32 flat) — else synthetic
    shard_id: int = 0
    num_shards: int = 1
    prefetch: int = 2


class _Synthetic:
    """Zipf-mixture token stream with local n-gram structure, so losses
    actually decrease during the examples' training runs."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg

    def batch_at(self, step: int) -> np.ndarray:
        cfg = self.cfg
        rng = np.random.default_rng(
            (cfg.seed * 1_000_003 + step) * cfg.num_shards + cfg.shard_id)
        B, S, V = cfg.batch, cfg.seq_len, cfg.vocab_size
        base = rng.zipf(1.3, size=(B, S)).astype(np.int64) % V
        # inject copy structure: spans repeat earlier content (learnable)
        for _ in range(2):
            src = rng.integers(0, S // 2, size=B)
            dst = rng.integers(S // 2, S - S // 4, size=B)
            ln = S // 8
            for b in range(B):
                base[b, dst[b]:dst[b] + ln] = base[b, src[b]:src[b] + ln]
        return base.astype(np.int32)


class _FileSource:
    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        self.tokens = np.memmap(cfg.path, dtype=np.int32, mode="r")

    def batch_at(self, step: int) -> np.ndarray:
        cfg = self.cfg
        n = cfg.batch * cfg.seq_len
        total = len(self.tokens) - n - 1
        off = ((step * cfg.num_shards + cfg.shard_id) * n) % max(total, 1)
        return np.asarray(self.tokens[off:off + n]).reshape(
            cfg.batch, cfg.seq_len)


class Pipeline:
    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        self.src = _FileSource(cfg) if cfg.path else _Synthetic(cfg)
        self._q: queue.Queue = queue.Queue(maxsize=cfg.prefetch)
        self._thread = None
        self._stop = threading.Event()

    def batch_at(self, step: int) -> np.ndarray:
        """Random access — used for deterministic restart replay."""
        return self.src.batch_at(step)

    def iterate(self, start_step: int = 0) -> Iterator[np.ndarray]:
        """Prefetching iterator starting at `start_step`."""
        self._stop.clear()

        def worker():
            s = start_step
            while not self._stop.is_set():
                self._q.put((s, self.src.batch_at(s)))
                s += 1

        self._thread = threading.Thread(target=worker, daemon=True)
        self._thread.start()
        while True:
            step, b = self._q.get()
            yield b

    def close(self):
        self._stop.set()
        if self._thread is not None:
            while not self._q.empty():
                self._q.get_nowait()
