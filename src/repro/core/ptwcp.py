"""PTW Cost Predictor (paper §5.2, Fig. 15/16, Table 2).

The production predictor is the 4-comparator bounding-box circuit: a page
is predicted costly-to-translate iff its (PTW cost, PTW frequency) counter
pair lies inside the box spanning (1,1)..(12,7):

    1 <= cost <= 12   (4-bit saturating counter, +1 per walk touching DRAM)
    1 <= freq <= 7    (3-bit saturating counter, +1 per walk)

Counters live in otherwise-unused PTE bits; here they are dense per-page
uint8 arrays updated by the MMU after every demand walk.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

FREQ_BITS = 3
COST_BITS = 4
FREQ_MAX = (1 << FREQ_BITS) - 1  # 7
COST_MAX = (1 << COST_BITS) - 1  # 15

# bounding box from Fig. 16 — (cost, freq) in (1,1)..(12,7)
BOX_COST_LO, BOX_COST_HI = 1, 12
BOX_FREQ_LO, BOX_FREQ_HI = 1, 7


class PageCounters(NamedTuple):
    freq: jax.Array  # uint8 [n_pages]
    cost: jax.Array  # uint8 [n_pages]


def make_counters(n_pages: int) -> PageCounters:
    return PageCounters(
        freq=jnp.zeros((n_pages,), jnp.uint8),
        cost=jnp.zeros((n_pages,), jnp.uint8),
    )


def update_counters(pc: PageCounters, page: jax.Array, had_dram, enable
                    ) -> PageCounters:
    """MMU updates after a demand PTW (saturating)."""
    en = jnp.asarray(enable)
    f = pc.freq[page]
    c = pc.cost[page]
    nf = jnp.minimum(f.astype(jnp.int32) + 1, FREQ_MAX).astype(jnp.uint8)
    nc = jnp.minimum(
        c.astype(jnp.int32) + jnp.asarray(had_dram).astype(jnp.int32), COST_MAX
    ).astype(jnp.uint8)
    return PageCounters(
        freq=pc.freq.at[page].set(jnp.where(en, nf, f)),
        cost=pc.cost.at[page].set(jnp.where(en, nc, c)),
    )


def predict(freq: jax.Array, cost: jax.Array) -> jax.Array:
    """The comparator tree — one cycle, 4 comparators, 4 threshold regs."""
    f = freq.astype(jnp.int32)
    c = cost.astype(jnp.int32)
    return (
        (c >= BOX_COST_LO) & (c <= BOX_COST_HI)
        & (f >= BOX_FREQ_LO) & (f <= BOX_FREQ_HI)
    )


def predict_page(pc: PageCounters, page: jax.Array) -> jax.Array:
    return predict(pc.freq[page], pc.cost[page])
