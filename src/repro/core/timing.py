"""Analytical end-to-end timing model (paper §3 calibration).

The trace-driven MMU model yields exact per-component cycle sums; end-to-
end execution time is reconstructed with a simple OoO model:

    cycles = instrs·CPI_exec                      (issue-limited base)
           + Σ translation_cycles                 (serial: gates the access)
           + (1-OVERLAP)·Σ (data_cycles - L1_hit) (MLP hides a fraction)

Constants are calibrated once so the *baseline* Radix system reproduces
the paper's §3 observation that ≈30% of execution cycles are spent on
address translation at L2-TLB MPKI ≈ 39; they are then frozen across every
evaluated system, so speedups are apples-to-apples.
"""
from __future__ import annotations

CPI_EXEC = 0.55      # 4-wide OoO core, issue-limited CPI
OVERLAP = 0.55       # fraction of data-miss latency hidden by MLP/OoO
L1_HIT_CYCLES = 4.0


def total_cycles(stats, ipa: float) -> float:
    n = float(stats.n_access)
    instrs = n * ipa
    trans = float(stats.sum_trans_cyc)
    data = float(stats.sum_data_cyc)
    data_stall = max(data - L1_HIT_CYCLES * n, 0.0) * (1.0 - OVERLAP)
    return instrs * CPI_EXEC + trans + data_stall


def translation_fraction(stats, ipa: float) -> float:
    return float(stats.sum_trans_cyc) / max(total_cycles(stats, ipa), 1.0)


def speedup(base_stats, new_stats, ipa: float) -> float:
    return total_cycles(base_stats, ipa) / max(total_cycles(new_stats, ipa), 1.0)


def mix_total_cycles(stats_list, ipa_list) -> float:
    """End-to-end cycles for a multiprogrammed mix: the cores run
    concurrently, so the co-schedule finishes when the slowest lane
    does (max over per-core analytical cycles)."""
    return max(total_cycles(s, ipa)
               for s, ipa in zip(stats_list, ipa_list))


def weighted_speedup(base_list, new_list, ipa_list) -> float:
    """Multiprogrammed speedup as the mean of per-core speedups (each
    lane vs the same lane under the baseline scheme) — the standard
    weighted-speedup metric for co-scheduled workloads."""
    per = [speedup(b, n, ipa)
           for b, n, ipa in zip(base_list, new_list, ipa_list)]
    return sum(per) / max(len(per), 1)
