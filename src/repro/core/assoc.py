"""Set-associative structure primitives (branchless, scan-friendly).

Every TLB / PWC / cache in ``repro.core`` is a pair-of-arrays structure

    tags  : int32  [n_sets, n_ways]
    valid : bool_  [n_sets, n_ways]
    meta  : int32  [n_sets, n_ways]   (LRU stamp or RRPV, policy-dependent)

All operations take a *dynamic* set index (traced scalar) and return pure
functional updates.  Victims are chosen branchlessly:

  * LRU    — argmin timestamp (invalid ways forced to -1 so they win).
  * SRRIP  — age all RRPVs by (RRIP_MAX - max RRPV) then argmax; the
             TLB-aware variant re-rolls once onto non-TLB ways per the
             paper's Listing 1.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

RRIP_BITS = 2
RRIP_MAX = (1 << RRIP_BITS) - 1  # 3


class Assoc(NamedTuple):
    """A set-associative array structure."""

    tags: jax.Array   # int32 [S, W]
    valid: jax.Array  # bool  [S, W]
    meta: jax.Array   # int32 [S, W] — LRU stamp or RRPV

    @property
    def n_sets(self) -> int:
        return self.tags.shape[0]

    @property
    def n_ways(self) -> int:
        return self.tags.shape[1]


def make(n_sets: int, n_ways: int) -> Assoc:
    return Assoc(
        tags=jnp.zeros((n_sets, n_ways), jnp.int32),
        valid=jnp.zeros((n_sets, n_ways), jnp.bool_),
        meta=jnp.zeros((n_sets, n_ways), jnp.int32),
    )


def set_index(key: jax.Array, n_sets: int) -> jax.Array:
    """Low-order-bit set indexing (n_sets must be a power of two)."""
    assert n_sets & (n_sets - 1) == 0, "n_sets must be a power of two"
    return key & (n_sets - 1)


def lookup(a: Assoc, key: jax.Array):
    """Probe. Returns (hit: bool scalar, way: int scalar, set_idx)."""
    s = set_index(key, a.n_sets)
    row_t = a.tags[s]
    row_v = a.valid[s]
    hits = row_v & (row_t == key)
    hit = jnp.any(hits)
    way = jnp.argmax(hits)  # first hitting way (0 if none; guard with `hit`)
    return hit, way, s


# ---------------------------------------------------------------- LRU


def touch_lru(a: Assoc, s: jax.Array, way: jax.Array, now: jax.Array) -> Assoc:
    return a._replace(meta=a.meta.at[s, way].set(now))


def lru_victim(a: Assoc, s: jax.Array) -> jax.Array:
    stamps = jnp.where(a.valid[s], a.meta[s], jnp.int32(-1))
    return jnp.argmin(stamps)


def insert_lru(a: Assoc, key: jax.Array, now: jax.Array, enable=True):
    """Insert `key` at set(key), evicting LRU. Returns (assoc, evicted_tag,
    evicted_valid)."""
    s = set_index(key, a.n_sets)
    w = lru_victim(a, s)
    ev_tag = a.tags[s, w]
    ev_valid = a.valid[s, w]
    en = jnp.asarray(enable)
    new = Assoc(
        tags=a.tags.at[s, w].set(jnp.where(en, key, a.tags[s, w])),
        valid=a.valid.at[s, w].set(jnp.where(en, True, a.valid[s, w])),
        meta=a.meta.at[s, w].set(jnp.where(en, now, a.meta[s, w])),
    )
    return new, ev_tag, ev_valid & en


# ------------------------------------------------- dynamic-size LRU views
#
# A structure allocated at its ladder-maximum shape can emulate any
# smaller power-of-two geometry with *traced* size parameters: the set
# index is masked with `set_mask` (= live_sets - 1) and victim selection
# is restricted to ways below `n_ways`.  Because inserts never touch
# ways >= n_ways, lookups and LRU choices are bit-identical to a
# statically allocated (live_sets, n_ways) structure — which is what
# lets one compiled step be vmapped across a whole size ladder.


def lookup_dyn(a: Assoc, key: jax.Array, set_mask: jax.Array,
               n_ways: jax.Array):
    """`lookup` against a dynamically sized view of `a`."""
    s = key & set_mask
    way_ok = jnp.arange(a.n_ways) < n_ways
    hits = a.valid[s] & (a.tags[s] == key) & way_ok
    return jnp.any(hits), jnp.argmax(hits), s


def insert_lru_dyn(a: Assoc, key: jax.Array, now: jax.Array,
                   set_mask: jax.Array, n_ways: jax.Array, enable=True):
    """`insert_lru` against a dynamically sized view of `a`."""
    s = key & set_mask
    way_ok = jnp.arange(a.n_ways) < n_ways
    stamps = jnp.where(way_ok,
                       jnp.where(a.valid[s], a.meta[s], jnp.int32(-1)),
                       jnp.iinfo(jnp.int32).max)
    w = jnp.argmin(stamps)
    ev_tag = a.tags[s, w]
    ev_valid = a.valid[s, w]
    en = jnp.asarray(enable)
    new = Assoc(
        tags=a.tags.at[s, w].set(jnp.where(en, key, a.tags[s, w])),
        valid=a.valid.at[s, w].set(jnp.where(en, True, a.valid[s, w])),
        meta=a.meta.at[s, w].set(jnp.where(en, now, a.meta[s, w])),
    )
    return new, ev_tag, ev_valid & en


# ---------------------------------------------------------------- SRRIP

def srrip_age_and_pick(rrpv_row: jax.Array, valid_row: jax.Array,
                       way_ok: jax.Array | None = None):
    """Age the row so at least one way reaches RRIP_MAX and pick a victim.

    Invalid ways are preferred (treated as RRPV=+inf).  `way_ok` (bool
    per way, optional) restricts both the aging max and the victim pick
    to a dynamically sized view's live ways: masked-off ways contribute
    -1 (they never dominate the max and never win the argmax), which
    keeps the view bit-identical to a statically smaller row.  Returns
    (aged_row, victim_way).
    """
    eff = jnp.where(valid_row, rrpv_row, jnp.int32(RRIP_MAX + 1))
    if way_ok is not None:
        eff = jnp.where(way_ok, eff, jnp.int32(-1))
    bump = jnp.maximum(RRIP_MAX - jnp.max(eff), 0)
    aged = jnp.where(valid_row, rrpv_row + bump, rrpv_row)
    pick = jnp.where(valid_row, aged, jnp.int32(RRIP_MAX + 1))
    if way_ok is not None:
        pick = jnp.where(way_ok, pick, jnp.int32(-1))
    victim = jnp.argmax(pick)
    return aged, victim


def srrip_victim_tlb_aware(
    rrpv_row: jax.Array,
    valid_row: jax.Array,
    is_tlb_row: jax.Array,
    pressure: jax.Array,
    way_ok: jax.Array | None = None,
):
    """Paper Listing 1 `chooseReplacementCandidate`.

    If the SRRIP victim is a TLB block and translation pressure is high,
    make ONE more attempt: choose a non-TLB way at RRIP_MAX (post-aging).
    If none exists the TLB block is evicted after all.
    Returns (aged_row, victim_way).
    """
    aged, v0 = srrip_age_and_pick(rrpv_row, valid_row, way_ok)
    # invalid ways already won in v0 if present
    non_tlb_max = valid_row & (~is_tlb_row) & (aged >= RRIP_MAX)
    if way_ok is not None:
        non_tlb_max = non_tlb_max & way_ok
    have_alt = jnp.any(non_tlb_max)
    v1 = jnp.argmax(non_tlb_max)
    reroll = pressure & valid_row[v0] & is_tlb_row[v0] & have_alt
    victim = jnp.where(reroll, v1, v0)
    return aged, victim
