"""Derived metrics over simulator Stats (the paper's reported quantities)."""
from __future__ import annotations

import numpy as np

KB = 1 << 10
MB = 1 << 20


def l2tlb_mpki(stats, ipa: float) -> float:
    instrs = float(stats.n_access) * ipa
    return float(stats.n_l2tlb_miss) * 1000.0 / max(instrs, 1.0)


def avg_walk_cycles(stats) -> float:
    return float(stats.sum_walk_cyc) / max(float(stats.n_demand_ptw), 1.0)


def avg_l2tlb_miss_latency(stats) -> float:
    """Cycles past the L2 TLB probe, averaged over L2-TLB misses
    (paper Figs. 9/22/29)."""
    return float(stats.sum_l2miss_cyc) / max(float(stats.n_l2tlb_miss), 1.0)


def reduction(base_n: float, new_n: float) -> float:
    """1 - new/base with a sane degenerate case: a baseline of zero means
    there is nothing to reduce, so the reduction is 0.0 — NOT the large
    negative number that ``1 - new/max(base, 1)`` used to produce.

    (The ``max(x, 1.0)`` guards in the *average* metrics above/below are
    safe as-is: whenever their denominator is 0 the numerator provably
    is too — no walks means no walk cycles — so they yield 0.0.)
    """
    b = float(base_n)
    return 0.0 if b == 0.0 else 1.0 - float(new_n) / b


def rate(num: float, den: float) -> float:
    """num/den with the same degenerate contract as ``reduction``: a
    denominator of zero means the event never happened, so the rate is
    0.0 — NOT ``num/max(den, 1)``, which silently reports a wrong
    nonzero value whenever ``num > 0 and den == 0`` can occur (and which
    hides bugs where it can't).  Every per-core metric routes through
    here so an idle lane in a multiprogrammed mix reports exactly 0.0.
    """
    d = float(den)
    return 0.0 if d == 0.0 else float(num) / d


def ptw_reduction(base_stats, new_stats) -> float:
    return reduction(base_stats.n_demand_ptw, new_stats.n_demand_ptw)


def per_core_ptw_reduction(base_stats, new_stats) -> list:
    """Per-core-lane PTW reductions for multicore results (each result is
    a tuple of per-core Stats).  Idle lanes — zero baseline walks — come
    out as 0.0 through ``reduction``'s base==0 guard rather than a
    nonsense negative number."""
    return [ptw_reduction(b, n) for b, n in zip(base_stats, new_stats)]


def mean_ptw_reduction(base_stats, new_stats) -> float:
    """Mean of the per-core PTW reductions (the multicore headline)."""
    per = per_core_ptw_reduction(base_stats, new_stats)
    return rate(sum(per), len(per))


def l3_translation_share(extras: dict) -> float:
    """Fraction of shared-L3 cache accesses that were translation
    traffic (TLB-block or PTE lines), from a shared-tier extras dict.
    Zero L3 accesses — e.g. an idle core lane — reports 0.0."""
    return rate(extras.get("l3_trans", 0), extras.get("l3_access", 0))


def dramc_hit_rate(extras: dict) -> float:
    """Die-stacked DRAM-cache hit rate from a shared-tier extras dict;
    0.0 when the DRAM cache is compiled out (no accesses)."""
    return rate(extras.get("dramc_hit", 0), extras.get("dramc_access", 0))


def host_ptw_reduction(base_stats, new_stats) -> float:
    """Virtualized runs: reduction in demand *host* walks (Fig. 28)."""
    return reduction(base_stats.n_host_ptw, new_stats.n_host_ptw)


def stage_hit_rates(stats) -> dict:
    """Fraction of accesses resolved at each translation level (the
    per-stage decomposition behind the MPKI/latency headlines)."""
    n = max(float(stats.n_access), 1.0)
    return {
        "l1_tlb": float(stats.n_l1tlb_hit) / n,
        "l2_tlb": float(stats.n_l2tlb_hit) / n,
        "victima": float(stats.n_victima_hit) / n,
        "l3_tlb": float(stats.n_l3tlb_hit) / n,
        "pom": float(stats.n_pom_hit) / n,
    }


def bg_walk_fraction(stats) -> float:
    """Fraction of all PTWs issued in the background (Victima's
    TLB-block promotion walks — off the critical path)."""
    total = float(stats.n_demand_ptw) + float(stats.n_bg_ptw)
    return float(stats.n_bg_ptw) / max(total, 1.0)


def nested_hit_rates(stats) -> dict:
    """Virtualized walks: per-access rates of nested-TLB and
    nested-Victima-block hits inside the 2D walker, next to the demand
    host-walk rate they displace."""
    n = max(float(stats.n_access), 1.0)
    return {
        "ntlb": float(stats.n_ntlb_hit) / n,
        "nvictima": float(stats.n_nvictima_hit) / n,
        "host_ptw": float(stats.n_host_ptw) / n,
    }


def rev_enroll_rate(stats) -> float:
    """Revelator enrollments per demand walk (signature-table ingest
    pressure: ~1.0 means every walk trains the table)."""
    return float(stats.n_rev_enroll) / max(float(stats.n_demand_ptw), 1.0)


def restseg_hit_rate(stats) -> float:
    """Fraction of RestSeg probes resolved without any FlexSeg walk
    (Utopia: probes happen on L2-TLB / Victima / L3 / POM misses)."""
    probes = float(stats.n_restseg_hit) + float(stats.n_restseg_miss)
    return float(stats.n_restseg_hit) / max(probes, 1.0)


def restseg_conflict_rate(stats) -> float:
    """Fraction of RestSeg migrations that demoted a resident page back
    to the FlexSeg (set-conflict pressure on the restrictive mapping)."""
    return float(stats.n_restseg_conflict) / max(
        float(stats.n_restseg_mig), 1.0)


def avg_restseg_probe_cycles(stats) -> float:
    probes = float(stats.n_restseg_hit) + float(stats.n_restseg_miss)
    return float(stats.sum_restseg_cyc) / max(probes, 1.0)


def rev_coverage(stats) -> float:
    """Fraction of L2-TLB misses the Revelator signature table resolved
    speculatively (correct predictions AND mispredictions — both skip
    the demand walker; a mispredict just pays the overlapped walk)."""
    resolved = float(stats.n_rev_hit) + float(stats.n_rev_mispred)
    return resolved / max(float(stats.n_l2tlb_miss), 1.0)


def rev_accuracy(stats) -> float:
    """Fraction of speculative translations that verified correct."""
    resolved = float(stats.n_rev_hit) + float(stats.n_rev_mispred)
    return float(stats.n_rev_hit) / max(resolved, 1.0)


def avg_rev_verify_cycles(stats) -> float:
    """Average verification-walk latency per speculative resolution
    (overlapped: critical-path only on mispredict)."""
    resolved = float(stats.n_rev_hit) + float(stats.n_rev_mispred)
    return float(stats.sum_rev_verify_cyc) / max(resolved, 1.0)


def translation_reach_mb(stats) -> float:
    """Average extra reach from TLB blocks resident in the L2 cache,
    *assuming 4KB pages* exactly as the paper's Fig. 23 does (8×4KB=32KB
    per block).  ``true_reach_mb`` weighs 2M blocks by real coverage."""
    n = max(float(stats.n_access), 1.0)
    blocks = (float(stats.sum_tlb4_live) + float(stats.sum_tlb2_live)) / n
    return blocks * 8 * 4 * KB / MB


def true_reach_mb(stats) -> float:
    n = max(float(stats.n_access), 1.0)
    avg4 = float(stats.sum_tlb4_live) / n
    avg2 = float(stats.sum_tlb2_live) / n
    return (avg4 * 8 * 4 * KB + avg2 * 8 * 2 * MB) / MB


def baseline_l2tlb_reach_mb(entries: int = 1536) -> float:
    return entries * 4 * KB / MB  # paper Fig. 23 assumes 4K pages


def reuse_distribution(hist: np.ndarray) -> np.ndarray:
    """Normalize a REUSE_BUCKETS histogram to fractions."""
    h = np.asarray(hist, dtype=np.float64)
    return h / max(h.sum(), 1.0)


def zero_reuse_fraction(hist: np.ndarray) -> float:
    return float(reuse_distribution(hist)[0])


def high_reuse_fraction(hist: np.ndarray, thresh: int = 21) -> float:
    """Fraction of blocks with reuse > 20 (paper Fig. 24 'high reuse')."""
    return float(reuse_distribution(hist)[thresh:].sum())


def _hist_fractions(hist) -> list:
    """(bucket_start_cycles, fraction) pairs on the 10-cycle grid."""
    h = np.asarray(hist, dtype=np.float64)
    frac = h / max(h.sum(), 1.0)
    return [(i * 10, f) for i, f in enumerate(frac)]


def walk_latency_histogram(stats):
    """(bucket_start_cycles, fraction) pairs for the Fig. 4 distribution."""
    return _hist_fractions(stats.hist_walk)


def restseg_probe_histogram(stats):
    """RestSeg tag-probe latency distribution (same grid as Fig. 4)."""
    return _hist_fractions(stats.hist_restseg)


def rev_verify_histogram(stats):
    """Revelator verification-walk latency distribution (overlapped;
    critical-path only on mispredict)."""
    return _hist_fractions(stats.hist_rev_verify)
