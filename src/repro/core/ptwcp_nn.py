"""Table-2 study: MLP predictors vs the comparator PTW-CP.

The paper trains NN-10/NN-5/NN-2 on per-page features to classify
"top-30% most costly-to-translate" pages, then distills NN-2's decision
boundary into the 4-comparator box.  We rebuild that pipeline on features
collected by the simulator (cfg.collect): NN-6 (all available features —
our NN-10 stand-in), NN-4, NN-2 (freq+cost only), and the comparator.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ptwcp


def build_dataset(extras_list):
    """From collect-mode extras: features + labels per touched page."""
    Xs, ys = [], []
    for ex in extras_list:
        ft = ex["feats"]
        pc = ex["pc4"]
        touched = np.asarray(ft.n_access) > 0
        idx = np.nonzero(touched)[0]
        freq = np.asarray(pc.freq)[: len(ft.n_access)][idx]
        cost = np.asarray(pc.cost)[: len(ft.n_access)][idx]
        feats = np.stack([
            np.asarray(ft.is2m)[idx].astype(np.float32),
            np.minimum(freq, 7).astype(np.float32),
            np.minimum(cost, 15).astype(np.float32),
            np.minimum(np.asarray(ft.n_access)[idx], 63).astype(np.float32),
            np.minimum(np.asarray(ft.n_l1_miss)[idx], 31).astype(np.float32),
            np.minimum(np.asarray(ft.n_l2_miss)[idx], 31).astype(np.float32),
        ], axis=1)
        wc = np.asarray(ft.walk_cyc)[idx]
        walked = wc > 0
        # top-30% most costly among pages that walked at all (paper §5.2)
        thr = np.quantile(wc[walked], 0.70) if walked.any() else 1.0
        Xs.append(feats)
        ys.append((wc >= max(thr, 1.0)).astype(np.float32))
    return np.concatenate(Xs), np.concatenate(ys)


@dataclasses.dataclass
class NNResult:
    name: str
    params_bytes: int
    accuracy: float
    precision: float
    recall: float
    f1: float


def _metrics(pred, y):
    tp = float(((pred == 1) & (y == 1)).sum())
    tn = float(((pred == 0) & (y == 0)).sum())
    fp = float(((pred == 1) & (y == 0)).sum())
    fn = float(((pred == 0) & (y == 1)).sum())
    acc = (tp + tn) / max(len(y), 1)
    prec = tp / max(tp + fp, 1)
    rec = tp / max(tp + fn, 1)
    f1 = 2 * prec * rec / max(prec + rec, 1e-9)
    return acc, prec, rec, f1


def train_mlp(X, y, feat_idx, hidden, layers=2, steps=300, seed=0,
              name="NN"):
    """Tiny MLP trained with Adam on the binary label."""
    Xs = jnp.asarray(X[:, feat_idx])
    mu, sd = Xs.mean(0), Xs.std(0) + 1e-6
    Xs = (Xs - mu) / sd
    yv = jnp.asarray(y)
    key = jax.random.PRNGKey(seed)
    dims = [len(feat_idx)] + [hidden] * layers + [1]
    ws = []
    for i in range(len(dims) - 1):
        key, k = jax.random.split(key)
        ws.append((jax.random.normal(k, (dims[i], dims[i + 1]))
                   / np.sqrt(dims[i]), jnp.zeros(dims[i + 1])))

    def fwd(ws, x):
        for w, b in ws[:-1]:
            x = jax.nn.relu(x @ w + b)
        w, b = ws[-1]
        return (x @ w + b)[:, 0]

    def loss(ws):
        logit = fwd(ws, Xs)
        return jnp.mean(
            jnp.maximum(logit, 0) - logit * yv
            + jnp.log1p(jnp.exp(-jnp.abs(logit))))

    lr = 0.05
    g_fn = jax.jit(jax.grad(loss))
    for t in range(steps):
        g = g_fn(ws)
        ws = jax.tree.map(lambda p, gg: p - lr * gg, ws, g)
    pred = (jax.nn.sigmoid(fwd(ws, Xs)) > 0.5).astype(np.float32)
    acc, prec, rec, f1 = _metrics(np.asarray(pred), y)
    nbytes = int(sum(w.size + b.size for w, b in ws) * 4)
    return NNResult(name, nbytes, acc, prec, rec, f1)


def comparator_result(X, y, box=None, name="Comparator(paper-box)"
                      ) -> NNResult:
    freq, cost = X[:, 1], X[:, 2]
    clo, chi, flo, fhi = box or (ptwcp.BOX_COST_LO, ptwcp.BOX_COST_HI,
                                 ptwcp.BOX_FREQ_LO, ptwcp.BOX_FREQ_HI)
    pred = ((cost >= clo) & (cost <= chi)
            & (freq >= flo) & (freq <= fhi)).astype(np.float32)
    acc, prec, rec, f1 = _metrics(pred, y)
    return NNResult(name, 24, acc, prec, rec, f1)


def fit_box(X, y):
    """The paper distills its comparator box from NN-2's decision pattern
    (Fig. 16); on our time-compressed traces the counters saturate at
    different rates, so we refit the 4 thresholds the same way (exhaustive
    search over the 16×16×8×8 grid, F1 objective)."""
    freq, cost = X[:, 1], X[:, 2]
    best, best_f1 = (1, 12, 1, 7), -1.0
    for clo in range(0, 8):
        for chi in range(clo, 16):
            for flo in range(0, 8):
                pred = ((cost >= clo) & (cost <= chi)
                        & (freq >= flo)).astype(np.float32)
                _, _, _, f1 = _metrics(pred, y)
                if f1 > best_f1:
                    best_f1, best = f1, (clo, chi, flo, 7)
    return best


def run_study(extras_list):
    X, y = build_dataset(extras_list)
    box = fit_box(X, y)
    results = [
        train_mlp(X, y, [0, 1, 2, 3, 4, 5], hidden=16, name="NN-6"),
        train_mlp(X, y, [1, 2, 3, 5], hidden=8, name="NN-4"),
        train_mlp(X, y, [1, 2], hidden=4, name="NN-2"),
        comparator_result(X, y),
        comparator_result(X, y, box,
                          name=f"Comparator(refit {box})"),
    ]
    return results
