"""Four-level radix page-table walk model (paper §2.2, Figs. 1 & 4).

Address map (64B-line ids, int32-safe):
  data lines            [0, 2^28)            line = va >> 6
  leaf PTE lines (4K)   LEAF4_BASE + vpn>>3  (8 PTEs / 64B line)
  PD lines              PD_BASE   + (vpn>>9)>>3   (also 2M leaf level)
  PDP lines             PDP_BASE  + (vpn>>18)>>3
  PML4 lines            PML4_BASE + (vpn>>27)>>3
  host PT lines (virt)  H*_BASE   + analogous, keyed by gpn
  POM-TLB lines         POM_BASE  + (vpn mod 64K)>>2
  RestSeg tag lines     RESTSEG*_BASE + set    (Utopia, one line per set)

The walker is equipped with 3 split PWCs covering PML4/PDP/PD (2-cycle,
Table 3); a PWC hit at depth d skips all accesses above d.  4K walks touch
up to 4 lines, 2M walks up to 3 (the PD entry is the leaf).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.assoc import Assoc, insert_lru, lookup, make
from repro.core.caches import Hier, L2Geom, Lat, access_pte

# line-id bases (disjoint regions; all < 2^30, int32-safe).
# Data lines occupy [0, 2^29): footprints up to 2^23 4K pages × 64 lines.
# Each PT region gets a 2^22-line window (leaf needs vpn>>3 ≤ 2^20).
_B = 1 << 29
_W = 1 << 22
LEAF4_BASE = _B + 0 * _W
PD_BASE = _B + 1 * _W
PDP_BASE = _B + 2 * _W
PML4_BASE = _B + 3 * _W
HLEAF_BASE = _B + 4 * _W
HPD_BASE = _B + 5 * _W
HPDP_BASE = _B + 6 * _W
HPML4_BASE = _B + 7 * _W
POM_BASE = _B + 8 * _W
RESTSEG4_BASE = _B + 9 * _W   # Utopia 4K RestSeg tag/permission lines
RESTSEG2_BASE = _B + 10 * _W  # Utopia 2M RestSeg tag/permission lines

PWC_LAT = 2


class PWCs(NamedTuple):
    pml4: Assoc  # keyed vpn>>27
    pdp: Assoc   # keyed vpn>>18
    pd: Assoc    # keyed vpn>>9

def make_pwcs(sets=8, ways=4) -> PWCs:
    return PWCs(pml4=make(sets, ways), pdp=make(sets, ways), pd=make(sets, ways))


def _level_lines_4k(vpn: jax.Array):
    return (
        PML4_BASE + ((vpn >> 27) >> 3),
        PDP_BASE + ((vpn >> 18) >> 3),
        PD_BASE + ((vpn >> 9) >> 3),
        LEAF4_BASE + (vpn >> 3),
    )


def _level_lines_2m(vpn2: jax.Array):
    # for 2M pages the PD entry is the leaf; walk depth 3
    return (
        PML4_BASE + ((vpn2 >> 18) >> 3),
        PDP_BASE + ((vpn2 >> 9) >> 3),
        PD_BASE + (vpn2 >> 3),
    )


def _host_lines(gpn: jax.Array):
    return (
        HPML4_BASE + ((gpn >> 27) >> 3),
        HPDP_BASE + ((gpn >> 18) >> 3),
        HPD_BASE + ((gpn >> 9) >> 3),
        HLEAF_BASE + (gpn >> 3),
    )


def walk(
    h: Hier,
    pwcs: PWCs,
    vpn4k: jax.Array,
    is2m: jax.Array,
    now: jax.Array,
    pressure: jax.Array,
    tlb_aware: bool,
    lat: Lat,
    enable,
    geom: L2Geom | None = None,
    dramc=None,
):
    """One native (or guest-PT-only) radix walk.

    Returns (hier, pwcs, cycles, n_dram).  `cycles` includes the PWC probe.
    All state updates are masked by `enable` (background walks pass True
    but callers discard `cycles`).  `geom` is the dynamic L2-cache view
    for ladder-batched runs (None = static geometry); `dramc` gates the
    die-stacked DRAM-cache probe (None = absent, compiled out).
    """
    en = jnp.asarray(enable)
    vpn2 = vpn4k >> 9

    l4k = _level_lines_4k(vpn4k)
    l2m = _level_lines_2m(vpn2)
    # unified 4-slot access plan; slot i line + which walk depth it is
    lines = [
        jnp.where(is2m, l2m[0], l4k[0]),
        jnp.where(is2m, l2m[1], l4k[1]),
        jnp.where(is2m, l2m[2], l4k[2]),
        l4k[3],
    ]
    n_levels = jnp.where(is2m, 3, 4)

    # PWC probes: keys per level (2M pages use vpn2-derived upper keys)
    k_pml4 = jnp.where(is2m, vpn2 >> 18, vpn4k >> 27)
    k_pdp = jnp.where(is2m, vpn2 >> 9, vpn4k >> 18)
    k_pd = vpn4k >> 9  # only meaningful for 4K walks
    hit4, _, _ = lookup(pwcs.pml4, k_pml4)
    hit3, _, _ = lookup(pwcs.pdp, k_pdp)
    hit2, _, _ = lookup(pwcs.pd, k_pd)
    hit2 = hit2 & ~is2m  # PD entries of 2M walks are leaves, not PWC-cached

    # deepest covered level → first slot that must be fetched
    # 4K: pd hit → start 3 (leaf only); pdp → 2; pml4 → 1; none → 0
    # 2M: pdp hit → start 2 (PD leaf); pml4 → 1; none → 0
    start = jnp.where(
        hit2, 3, jnp.where(hit3, 2, jnp.where(hit4, 1, 0))
    )
    start = jnp.where(is2m, jnp.minimum(start, 2), start)

    cycles = jnp.where(en, jnp.int32(PWC_LAT), 0)
    n_dram = jnp.int32(0)
    for slot in range(4):
        slot_en = en & (slot >= start) & (slot < n_levels)
        h, c, d = access_pte(h, lines[slot], pressure, tlb_aware, lat,
                             slot_en, geom=geom, dramc=dramc)
        cycles = cycles + c
        n_dram = n_dram + d.astype(jnp.int32)

    # fill PWCs for the upper levels just walked
    p4, _, _ = insert_lru(pwcs.pml4, k_pml4, now, en & (start <= 0))
    p3, _, _ = insert_lru(pwcs.pdp, k_pdp, now, en & (start <= 1))
    p2, _, _ = insert_lru(pwcs.pd, k_pd, now, en & (start <= 2) & ~is2m)
    return h, PWCs(pml4=p4, pdp=p3, pd=p2), cycles, n_dram


def host_walk(h: Hier, gpn: jax.Array, pressure: jax.Array,
              tlb_aware: bool, lat: Lat, enable,
              geom: L2Geom | None = None, dramc=None):
    """Host-PT walk (virt., no PWCs — paper Fig. 3 gives the host walker a
    nested TLB instead). 4 sequential PTE-line accesses through the caches.
    Returns (hier, cycles, n_dram, leaf_line)."""
    en = jnp.asarray(enable)
    lines = _host_lines(gpn)
    cycles = jnp.int32(0)
    n_dram = jnp.int32(0)
    for ln in lines:
        h, c, d = access_pte(h, ln, pressure, tlb_aware, lat, en, geom=geom,
                             dramc=dramc)
        cycles = cycles + c
        n_dram = n_dram + d.astype(jnp.int32)
    return h, cycles, n_dram, lines[3]
