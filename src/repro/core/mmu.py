"""Unified MMU + Victima model (paper §§4-6, Table 3).

One scan-step function covers every evaluated system; the static
``SimConfig`` specializes the compiled code path:

  Radix            — baseline 2-level TLB + 4-level radix PTW
  Opt/Real L2 TLB  — bigger L2 TLB, optimistic (12cyc) or CACTI latency
  Opt L3 TLB       — hardware L3 TLB behind the L2 TLB
  POM-TLB          — 64K-entry software-managed L3 TLB resident in memory
  Victima          — TLB blocks in the L2 cache + PTW-CP + TLB-aware SRRIP
  NP / I-SP        — virtualized: nested paging (2-D walk + nested TLB,
                     optionally with Victima TLB & nested-TLB blocks) or
                     ideal shadow paging (1-D walk)

State is a NamedTuple of integer arrays; every update is a masked scalar/row
scatter so a jitted ``lax.scan`` simulates ~1M accesses in seconds on CPU.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import ptwcp
from repro.core.assoc import Assoc, insert_lru, lookup, make, set_index
from repro.core.caches import (
    BT_DATA,
    BT_NTLB,
    BT_TLB2,
    BT_TLB4,
    Hier,
    Lat,
    access_data,
    access_pte,
    l2_lookup,
    l2_retag_to_tlb,
    l2_touch,
    make_hier,
)
from repro.core.page_table import POM_BASE, PWCs, host_walk, make_pwcs, walk

WALK_HIST_BUCKETS = 64  # 10-cycle buckets for the Fig.4 PTW latency CDF


@dataclasses.dataclass(frozen=True)
class SimConfig:
    """Static simulation configuration (Table 3 defaults)."""

    # --- TLB hierarchy
    l1d4_sets: int = 16   # 64-entry, 4-way (4K pages)
    l1d4_ways: int = 4
    l1d2_sets: int = 8    # 32-entry, 4-way (2M pages)
    l1d2_ways: int = 4
    l1tlb_lat: int = 1
    l2tlb_sets: int = 128  # 1536-entry, 12-way
    l2tlb_ways: int = 12
    l2tlb_lat: int = 12
    # --- optional hardware L3 TLB (0 sets = absent)
    l3tlb_sets: int = 0
    l3tlb_ways: int = 16
    l3tlb_lat: int = 15
    # --- POM-TLB (software L3 TLB resident in memory)
    pom: bool = False
    pom_sets: int = 4096  # 64K entries, 16-way
    pom_ways: int = 16
    # --- Victima
    victima: bool = False
    tlb_aware: bool = True       # TLB-aware SRRIP at the L2 cache
    use_ptwcp: bool = True       # False = insert every candidate (ablation)
    bypass_l2mpki: float = 5.0   # consult PTW-CP only if L2$ MPKI below this
    pressure_mpki: float = 5.0   # "translation pressure" threshold
    # --- caches
    l1_sets: int = 64
    l1_ways: int = 8
    l2_sets: int = 2048   # 2MB
    l2_ways: int = 16
    l3_sets: int = 2048   # 2MB/core
    l3_ways: int = 16
    lat: Lat = Lat()
    # --- virtualization
    virt: bool = False           # nested paging 2-D walk
    ideal_shadow: bool = False   # I-SP: 1-D shadow walk, free updates
    ntlb_sets: int = 16          # 64-entry nested TLB
    ntlb_ways: int = 4
    # --- bookkeeping
    n_pages4: int = 1 << 21      # 4K-page counter-table entries (masked vpn;
    #   larger footprints alias — counters are advisory predictor state and
    #   XLA-CPU copies of >2M-entry carry arrays dominate sim runtime)
    n_pages2: int = 1 << 14      # 2M-page counter-table entries
    n_pagesh: int = 1 << 14      # host-page counter table (hashed, virt;
    #   small: 10 scatter/gather per virt step — see fused-counter note)
    ipa: float = 3.0             # instructions per traced memory access
    collect: bool = False        # per-page feature collection (Table 2)
    n_feat: int = 1 << 20        # feature-table entries (hashed vpn)


class Stats(NamedTuple):
    n_access: jax.Array
    n_l1tlb_hit: jax.Array
    n_l2tlb_hit: jax.Array
    n_l2tlb_miss: jax.Array
    n_victima_hit: jax.Array
    n_l3tlb_hit: jax.Array
    n_pom_hit: jax.Array
    n_demand_ptw: jax.Array      # native / guest demand walks
    n_bg_ptw: jax.Array
    n_host_ptw: jax.Array        # virt: demand host walks
    n_ntlb_hit: jax.Array
    n_nvictima_hit: jax.Array    # nested-TLB-block hits in L2 cache
    sum_trans_cyc: jax.Array     # f32
    sum_l2miss_cyc: jax.Array    # f32 — translation cycles past the L2 TLB
    sum_data_cyc: jax.Array      # f32
    sum_walk_cyc: jax.Array      # f32 — demand walk cycles only
    hist_walk: jax.Array         # i32 [WALK_HIST_BUCKETS]
    sum_tlb4_live: jax.Array     # f32 — Σ live TLB blocks (reach, Fig 23)
    sum_tlb2_live: jax.Array     # f32


def _zero_stats() -> Stats:
    z = jnp.int32(0)
    f = jnp.float32(0)
    return Stats(
        n_access=z, n_l1tlb_hit=z, n_l2tlb_hit=z, n_l2tlb_miss=z,
        n_victima_hit=z, n_l3tlb_hit=z, n_pom_hit=z, n_demand_ptw=z,
        n_bg_ptw=z, n_host_ptw=z, n_ntlb_hit=z, n_nvictima_hit=z,
        sum_trans_cyc=f, sum_l2miss_cyc=f, sum_data_cyc=f, sum_walk_cyc=f,
        hist_walk=jnp.zeros((WALK_HIST_BUCKETS,), jnp.int32),
        sum_tlb4_live=f, sum_tlb2_live=f,
    )


class Feats(NamedTuple):
    """Per-page features for the Table-2 predictor study (hashed table)."""
    n_access: jax.Array     # uint16
    n_l1_miss: jax.Array    # uint16
    n_l2_miss: jax.Array    # uint16 — L2 TLB misses
    n_walk: jax.Array       # uint16 — unsaturated walk count
    walk_cyc: jax.Array     # float32 — Σ demand-walk cycles (label source)
    is2m: jax.Array         # uint8


def _zero_feats(n: int) -> Feats:
    return Feats(
        n_access=jnp.zeros((n,), jnp.uint16),
        n_l1_miss=jnp.zeros((n,), jnp.uint16),
        n_l2_miss=jnp.zeros((n,), jnp.uint16),
        n_walk=jnp.zeros((n,), jnp.uint16),
        walk_cyc=jnp.zeros((n,), jnp.float32),
        is2m=jnp.zeros((n,), jnp.uint8),
    )


class MMUState(NamedTuple):
    now: jax.Array
    l1d4: Assoc
    l1d2: Assoc
    l2tlb: Assoc
    l3tlb: Assoc
    pom: Assoc
    pwcs: PWCs
    hier: Hier
    ntlb: Assoc
    pc4: ptwcp.PageCounters
    pc2: ptwcp.PageCounters
    pch: ptwcp.PageCounters
    feats: Feats
    stats: Stats


def make_state(cfg: SimConfig) -> MMUState:
    return MMUState(
        now=jnp.int32(0),
        l1d4=make(cfg.l1d4_sets, cfg.l1d4_ways),
        l1d2=make(cfg.l1d2_sets, cfg.l1d2_ways),
        l2tlb=make(cfg.l2tlb_sets, cfg.l2tlb_ways),
        l3tlb=make(max(cfg.l3tlb_sets, 1), cfg.l3tlb_ways),
        pom=make(cfg.pom_sets if cfg.pom else 1, cfg.pom_ways),
        pwcs=make_pwcs(),
        hier=make_hier(cfg.l1_sets, cfg.l1_ways, cfg.l2_sets, cfg.l2_ways,
                       cfg.l3_sets, cfg.l3_ways),
        ntlb=make(cfg.ntlb_sets if cfg.virt else 1, cfg.ntlb_ways),
        pc4=ptwcp.make_counters(cfg.n_pages4),
        pc2=ptwcp.make_counters(cfg.n_pages2),
        pch=ptwcp.make_counters(cfg.n_pagesh if cfg.virt else 1),
        feats=_zero_feats(cfg.n_feat if cfg.collect else 1),
        stats=_zero_stats(),
    )


def _hash_h(x: jax.Array, n: int) -> jax.Array:
    return (x * jnp.int32(-1640531535)) & (n - 1)


def _nested_translate(cfg: SimConfig, st: MMUState, gpn: jax.Array,
                      pressure, l2_bypass, enable):
    """gPA-page → hPA (virt.): nested TLB → [Victima nested-TLB block] →
    host walk.  Returns (st, cycles, host_walked)."""
    en = jnp.asarray(enable)
    hit_n, w_n, s_n = lookup(st.ntlb, gpn)
    ntlb = st.ntlb._replace(
        meta=st.ntlb.meta.at[s_n, w_n].set(
            jnp.where(en & hit_n, st.now, st.ntlb.meta[s_n, w_n])
        )
    )
    st = st._replace(ntlb=ntlb)

    miss = en & ~hit_n
    cycles = jnp.where(en, 1, 0)  # 1-cycle nested TLB

    # Victima: probe L2 cache for a nested TLB block
    if cfg.victima:
        vh, vw, vs = l2_lookup(st.hier.l2, gpn >> 3, BT_NTLB)
        vhit = miss & vh
        l2c = l2_touch(st.hier.l2, vs, vw, pressure, cfg.tlb_aware, vhit)
        st = st._replace(hier=st.hier._replace(l2=l2c))
        cycles = cycles + jnp.where(vhit, cfg.lat.l2, 0)
    else:
        vhit = jnp.bool_(False)

    need_walk = miss & ~vhit
    hier, wc, ndram, _leaf = host_walk(
        st.hier, gpn, pressure, cfg.tlb_aware, cfg.lat, need_walk
    )
    st = st._replace(hier=hier)
    cycles = cycles + wc

    # host-page PTW-CP counters + nested-TLB-block insertion
    hidx = _hash_h(gpn, cfg.n_pagesh)
    pch = ptwcp.update_counters(st.pch, hidx, ndram >= 1, need_walk)
    st = st._replace(pch=pch)
    if cfg.victima:
        pred = ptwcp.predict_page(pch, hidx) if cfg.use_ptwcp else jnp.bool_(True)
        ins = need_walk & (pred | l2_bypass)
        l2c = l2_retag_to_tlb(st.hier.l2, gpn >> 3, BT_NTLB, pressure,
                              cfg.tlb_aware, ins)
        st = st._replace(hier=st.hier._replace(l2=l2c))

    # refill nested TLB; evicted nested entry triggers background host walk
    ntlb2, ev_tag, ev_valid = insert_lru(st.ntlb, gpn, st.now, miss)
    st = st._replace(ntlb=ntlb2)
    if cfg.victima:
        eidx = _hash_h(ev_tag, cfg.n_pagesh)
        epred = ptwcp.predict_page(st.pch, eidx) if cfg.use_ptwcp else jnp.bool_(True)
        bg = miss & ev_valid & (epred | l2_bypass)
        hier, _, bdram, _ = host_walk(st.hier, ev_tag, pressure,
                                      cfg.tlb_aware, cfg.lat, bg)
        pch = ptwcp.update_counters(st.pch, eidx, bdram >= 1, bg)
        l2c = l2_retag_to_tlb(hier.l2, ev_tag >> 3, BT_NTLB, pressure,
                              cfg.tlb_aware, bg)
        st = st._replace(hier=hier._replace(l2=l2c), pch=pch)

    return st, cycles, need_walk, en & hit_n, vhit


def _guest_walk_2d(cfg: SimConfig, st: MMUState, vpn: jax.Array,
                   is2m, pressure, l2_bypass, enable):
    """Nested-paging 2-D walk: every guest-PT access first resolves its own
    gPA→hPA via ``_nested_translate``.  Returns (st, cycles, n_dram,
    n_host_walks)."""
    from repro.core.page_table import (PWC_LAT, _level_lines_2m,
                                       _level_lines_4k)

    en = jnp.asarray(enable)
    vpn2 = vpn >> 9
    l4k = _level_lines_4k(vpn)
    l2m = _level_lines_2m(vpn2)
    lines = [
        jnp.where(is2m, l2m[0], l4k[0]),
        jnp.where(is2m, l2m[1], l4k[1]),
        jnp.where(is2m, l2m[2], l4k[2]),
        l4k[3],
    ]
    n_levels = jnp.where(is2m, 3, 4)

    k_pml4 = jnp.where(is2m, vpn2 >> 18, vpn >> 27)
    k_pdp = jnp.where(is2m, vpn2 >> 9, vpn >> 18)
    k_pd = vpn >> 9
    hit4, _, _ = lookup(st.pwcs.pml4, k_pml4)
    hit3, _, _ = lookup(st.pwcs.pdp, k_pdp)
    hit2, _, _ = lookup(st.pwcs.pd, k_pd)
    hit2 = hit2 & ~is2m
    start = jnp.where(hit2, 3, jnp.where(hit3, 2, jnp.where(hit4, 1, 0)))
    start = jnp.where(is2m, jnp.minimum(start, 2), start)

    cycles = jnp.where(en, jnp.int32(PWC_LAT), 0)
    n_dram = jnp.int32(0)
    n_host = jnp.int32(0)
    n_nt_hit = jnp.int32(0)
    n_nv_hit = jnp.int32(0)
    for slot in range(4):
        slot_en = en & (slot >= start) & (slot < n_levels)
        # translate the guest-PT line's gPA page first
        st, ncyc, walked, nth, nvh = _nested_translate(
            cfg, st, lines[slot] >> 6, pressure, l2_bypass, slot_en
        )
        n_host = n_host + (walked & slot_en).astype(jnp.int32)
        n_nt_hit = n_nt_hit + nth.astype(jnp.int32)
        n_nv_hit = n_nv_hit + nvh.astype(jnp.int32)
        hier, c, d = access_pte(st.hier, lines[slot], pressure,
                                cfg.tlb_aware, cfg.lat, slot_en)
        st = st._replace(hier=hier)
        cycles = cycles + ncyc + c
        n_dram = n_dram + d.astype(jnp.int32)

    p4, _, _ = insert_lru(st.pwcs.pml4, k_pml4, st.now, en & (start <= 0))
    p3, _, _ = insert_lru(st.pwcs.pdp, k_pdp, st.now, en & (start <= 1))
    p2, _, _ = insert_lru(st.pwcs.pd, k_pd, st.now, en & (start <= 2) & ~is2m)
    st = st._replace(pwcs=PWCs(pml4=p4, pdp=p3, pd=p2))

    # finally translate the data page's own gPA (gpn = vpn, identity map)
    st, ncyc, walked, nth, nvh = _nested_translate(
        cfg, st, vpn, pressure, l2_bypass, en)
    n_host = n_host + (walked & en).astype(jnp.int32)
    n_nt_hit = n_nt_hit + nth.astype(jnp.int32)
    n_nv_hit = n_nv_hit + nvh.astype(jnp.int32)
    return st, cycles + ncyc, n_dram, n_host, n_nt_hit, n_nv_hit


def make_step(cfg: SimConfig):
    """Build the scan-step for this configuration.

    Trace record: dict(vpn=int32 4K-VPN, is2m=bool, line=int32 data line id,
    ipa=float32 — per-trace instructions/access so a vmapped batch of
    workloads shares one compiled step).
    """
    pressure_thr = jnp.float32(cfg.pressure_mpki)
    bypass_thr = jnp.float32(cfg.bypass_l2mpki)

    def step(st: MMUState, acc):
        vpn = acc["vpn"]
        is2m = acc["is2m"]
        line = acc["line"]
        ipa = acc.get("ipa", jnp.float32(cfg.ipa))
        now = st.now + 1
        st = st._replace(now=now)
        s0 = st.stats

        instrs = jnp.maximum(s0.n_access.astype(jnp.float32), 1.0) * ipa
        pressure = (s0.n_l2tlb_miss.astype(jnp.float32) * 1000.0
                    > pressure_thr * instrs)
        l2_bypass = (st.hier.n_l2_miss.astype(jnp.float32) * 1000.0
                     >= bypass_thr * instrs)

        vpn2 = vpn >> 9
        vpn_sz = jnp.where(is2m, vpn2, vpn)

        # ---------------- L1 D-TLBs (split by page size)
        h4, w4, s4 = lookup(st.l1d4, vpn)
        h2, w2, s2 = lookup(st.l1d2, vpn2)
        hit1 = jnp.where(is2m, h2, h4)
        l1d4 = st.l1d4._replace(meta=st.l1d4.meta.at[s4, w4].set(
            jnp.where(h4 & ~is2m, now, st.l1d4.meta[s4, w4])))
        l1d2 = st.l1d2._replace(meta=st.l1d2.meta.at[s2, w2].set(
            jnp.where(h2 & is2m, now, st.l1d2.meta[s2, w2])))
        st = st._replace(l1d4=l1d4, l1d2=l1d2)

        # ---------------- unified L2 TLB
        key2 = (vpn_sz << 1) | is2m.astype(jnp.int32)
        ht, wt, stt = lookup(st.l2tlb, key2)
        miss1 = ~hit1
        l2tlb_hit = miss1 & ht
        miss2 = miss1 & ~ht
        l2tlb = st.l2tlb._replace(meta=st.l2tlb.meta.at[stt, wt].set(
            jnp.where(l2tlb_hit, now, st.l2tlb.meta[stt, wt])))
        st = st._replace(l2tlb=l2tlb)

        trans = jnp.int32(cfg.l1tlb_lat)
        trans = trans + jnp.where(miss1, cfg.l2tlb_lat, 0)
        past_l2 = jnp.int32(0)  # cycles after the L2 TLB probe (Fig 9/22/29)

        # ---------------- Victima: TLB-block probe in the L2 cache
        if cfg.victima:
            vkey = jnp.where(is2m, vpn2 >> 3, vpn >> 3)
            vbt = jnp.where(is2m, BT_TLB2, BT_TLB4)
            # typed lookup (btype must match)
            sset = set_index(vkey, st.hier.l2.n_sets)
            rows_hit = (st.hier.l2.valid[sset]
                        & (st.hier.l2.tags[sset] == vkey)
                        & (st.hier.l2.btype[sset] == vbt))
            vh = jnp.any(rows_hit)
            vwy = jnp.argmax(rows_hit)
            vhit = miss2 & vh
            l2c = l2_touch(st.hier.l2, sset, vwy, pressure, cfg.tlb_aware, vhit)
            st = st._replace(hier=st.hier._replace(l2=l2c))
            past_l2 = past_l2 + jnp.where(vhit, cfg.lat.l2, 0)
        else:
            vhit = jnp.bool_(False)

        need_more = miss2 & ~vhit

        # ---------------- optional hardware L3 TLB
        if cfg.l3tlb_sets > 0:
            h3, w3, s3 = lookup(st.l3tlb, key2)
            l3hit = need_more & h3
            l3tlb = st.l3tlb._replace(meta=st.l3tlb.meta.at[s3, w3].set(
                jnp.where(l3hit, now, st.l3tlb.meta[s3, w3])))
            st = st._replace(l3tlb=l3tlb)
            past_l2 = past_l2 + jnp.where(need_more, cfg.l3tlb_lat, 0)
            need_more = need_more & ~h3
        else:
            l3hit = jnp.bool_(False)

        # ---------------- POM-TLB (software L3, entries fetched via caches)
        if cfg.pom:
            pom_line = POM_BASE + ((key2 & ((cfg.pom_sets * cfg.pom_ways) - 1)) >> 2)
            hier, pc_cyc, _ = access_pte(
                st.hier, pom_line, pressure, cfg.tlb_aware, cfg.lat,
                need_more, bt=BT_TLB4,
            )
            st = st._replace(hier=hier)
            hp, wp, sp = lookup(st.pom, key2)
            pomhit = need_more & hp
            pom = st.pom._replace(meta=st.pom.meta.at[sp, wp].set(
                jnp.where(pomhit, now, st.pom.meta[sp, wp])))
            st = st._replace(pom=pom)
            past_l2 = past_l2 + pc_cyc
            need_more = need_more & ~hp
        else:
            pomhit = jnp.bool_(False)

        # ---------------- page-table walk (demand)
        walk_en = need_more
        if cfg.virt and not cfg.ideal_shadow:
            st, wcyc, ndram, nhost, n_nt_hit, n_nv_hit = _guest_walk_2d(
                cfg, st, vpn, is2m, pressure, l2_bypass, walk_en
            )
        else:
            hier, pwcs, wcyc, ndram = walk(
                st.hier, st.pwcs, vpn, is2m, now, pressure,
                cfg.tlb_aware, cfg.lat, walk_en,
            )
            st = st._replace(hier=hier, pwcs=pwcs)
            nhost = jnp.int32(0)
            n_nt_hit = jnp.int32(0)
            n_nv_hit = jnp.int32(0)
        past_l2 = past_l2 + wcyc

        n_bg = jnp.int32(0)
        if not cfg.victima:
            # PTW-CP counters for the walked page
            pc4 = ptwcp.update_counters(
                st.pc4, vpn & (cfg.n_pages4 - 1), ndram >= 1,
                walk_en & ~is2m)
            pc2 = ptwcp.update_counters(
                st.pc2, vpn2 & (cfg.n_pages2 - 1), ndram >= 1,
                walk_en & is2m)
            st = st._replace(pc4=pc4, pc2=pc2)
            l2tlb2, ev_tag, ev_valid = insert_lru(st.l2tlb, key2, now, miss2)
            st = st._replace(l2tlb=l2tlb2)
        else:
            # ---------------- Victima flows. All counter-table traffic is
            # fused into ONE gather + ONE scatter per array so the XLA CPU
            # backend keeps the (multi-MB) tables in place across the scan.
            l2tlb2, ev_tag, ev_valid = insert_lru(st.l2tlb, key2, now, miss2)
            st = st._replace(l2tlb=l2tlb2)
            ev_vpn = ev_tag >> 1
            ev2m = (ev_tag & 1).astype(jnp.bool_)
            bg_vpn4 = jnp.where(ev2m, ev_vpn << 9, ev_vpn)

            i4 = jnp.stack([vpn & (cfg.n_pages4 - 1),
                            bg_vpn4 & (cfg.n_pages4 - 1)])
            i2 = jnp.stack([vpn2 & (cfg.n_pages2 - 1),
                            ev_vpn & (cfg.n_pages2 - 1)])
            f4, c4 = st.pc4.freq[i4].astype(jnp.int32), \
                st.pc4.cost[i4].astype(jnp.int32)
            f2, c2 = st.pc2.freq[i2].astype(jnp.int32), \
                st.pc2.cost[i2].astype(jnp.int32)

            # demand prediction on post-walk counters (computed analytically)
            fpost = jnp.where(is2m, f2[0], f4[0]) + walk_en.astype(jnp.int32)
            cpost = jnp.where(is2m, c2[0], c4[0]) \
                + (walk_en & (ndram >= 1)).astype(jnp.int32)
            pred = ptwcp.predict(jnp.minimum(fpost, ptwcp.FREQ_MAX),
                                 jnp.minimum(cpost, ptwcp.COST_MAX))
            pred = pred if cfg.use_ptwcp else jnp.bool_(True)
            ins = walk_en & (pred | l2_bypass)
            l2c = l2_retag_to_tlb(st.hier.l2, vkey, vbt, pressure,
                                  cfg.tlb_aware, ins)
            st = st._replace(hier=st.hier._replace(l2=l2c))

            # eviction-triggered background walk + TLB-block install
            fe = jnp.where(ev2m, f2[1], f4[1])
            ce = jnp.where(ev2m, c2[1], c4[1])
            epred = ptwcp.predict(fe, ce)
            epred = epred if cfg.use_ptwcp else jnp.bool_(True)
            bg = miss2 & ev_valid & (epred | l2_bypass)
            hier, pwcs, _, bdram = walk(
                st.hier, st.pwcs, bg_vpn4, ev2m, now, pressure,
                cfg.tlb_aware, cfg.lat, bg,
            )
            ebt = jnp.where(ev2m, BT_TLB2, BT_TLB4)
            l2c = l2_retag_to_tlb(hier.l2, ev_vpn >> 3, ebt, pressure,
                                  cfg.tlb_aware, bg)
            st = st._replace(hier=hier._replace(l2=l2c), pwcs=pwcs)
            n_bg = bg.astype(jnp.int32)

            # fused saturating counter writeback (2 slots per table)
            en4 = jnp.stack([walk_en & ~is2m, bg & ~ev2m])
            en2 = jnp.stack([walk_en & is2m, bg & ev2m])
            dr = jnp.stack([ndram >= 1, bdram >= 1])
            nf4 = jnp.minimum(f4 + en4, ptwcp.FREQ_MAX)
            nc4 = jnp.minimum(c4 + (en4 & dr), ptwcp.COST_MAX)
            nf2 = jnp.minimum(f2 + en2, ptwcp.FREQ_MAX)
            nc2 = jnp.minimum(c2 + (en2 & dr), ptwcp.COST_MAX)
            st = st._replace(
                pc4=ptwcp.PageCounters(
                    freq=st.pc4.freq.at[i4].set(nf4.astype(jnp.uint8)),
                    cost=st.pc4.cost.at[i4].set(nc4.astype(jnp.uint8))),
                pc2=ptwcp.PageCounters(
                    freq=st.pc2.freq.at[i2].set(nf2.astype(jnp.uint8)),
                    cost=st.pc2.cost.at[i2].set(nc2.astype(jnp.uint8))),
            )

        # POM-TLB learns walked + evicted entries
        if cfg.pom:
            pom2, _, _ = insert_lru(st.pom, key2, now, walk_en)
            pom2, _, _ = insert_lru(pom2, ev_tag, now, miss2 & ev_valid)
            st = st._replace(pom=pom2)
        if cfg.l3tlb_sets > 0:
            l3t, _, _ = insert_lru(st.l3tlb, key2, now, walk_en)
            st = st._replace(l3tlb=l3t)

        # refill L1 TLB
        l1d4b, _, _ = insert_lru(st.l1d4, vpn, now, miss1 & ~is2m)
        l1d2b, _, _ = insert_lru(st.l1d2, vpn2, now, miss1 & is2m)
        st = st._replace(l1d4=l1d4b, l1d2=l1d2b)

        trans = trans + past_l2

        # ---------------- the data access itself
        hier, dcyc = access_data(st.hier, line, now, pressure,
                                 cfg.tlb_aware, cfg.lat)
        st = st._replace(hier=hier)

        # ---------------- stats
        bucket = jnp.minimum(wcyc // 10, WALK_HIST_BUCKETS - 1)
        l2 = st.hier.l2
        stats = Stats(
            n_access=s0.n_access + 1,
            n_l1tlb_hit=s0.n_l1tlb_hit + hit1.astype(jnp.int32),
            n_l2tlb_hit=s0.n_l2tlb_hit + l2tlb_hit.astype(jnp.int32),
            n_l2tlb_miss=s0.n_l2tlb_miss + miss2.astype(jnp.int32),
            n_victima_hit=s0.n_victima_hit + vhit.astype(jnp.int32),
            n_l3tlb_hit=s0.n_l3tlb_hit + l3hit.astype(jnp.int32),
            n_pom_hit=s0.n_pom_hit + pomhit.astype(jnp.int32),
            n_demand_ptw=s0.n_demand_ptw + walk_en.astype(jnp.int32),
            n_bg_ptw=s0.n_bg_ptw + n_bg,
            n_host_ptw=s0.n_host_ptw + nhost,
            n_ntlb_hit=s0.n_ntlb_hit + n_nt_hit,
            n_nvictima_hit=s0.n_nvictima_hit + n_nv_hit,
            sum_trans_cyc=s0.sum_trans_cyc + trans.astype(jnp.float32),
            sum_l2miss_cyc=s0.sum_l2miss_cyc
            + jnp.where(miss2, past_l2, 0).astype(jnp.float32),
            sum_data_cyc=s0.sum_data_cyc + dcyc.astype(jnp.float32),
            sum_walk_cyc=s0.sum_walk_cyc
            + jnp.where(walk_en, wcyc, 0).astype(jnp.float32),
            hist_walk=s0.hist_walk.at[bucket].add(walk_en.astype(jnp.int32)),
            sum_tlb4_live=s0.sum_tlb4_live + l2.n_tlb4.astype(jnp.float32),
            sum_tlb2_live=s0.sum_tlb2_live + l2.n_tlb2.astype(jnp.float32),
        )
        st = st._replace(stats=stats)

        if cfg.collect:  # Table-2 per-page feature stream
            fi = (vpn_sz * jnp.int32(-1640531535)) & (cfg.n_feat - 1)
            ft = st.feats
            u1 = jnp.uint16(1)
            st = st._replace(feats=Feats(
                n_access=ft.n_access.at[fi].add(u1),
                n_l1_miss=ft.n_l1_miss.at[fi].add(
                    jnp.where(miss1, u1, 0).astype(jnp.uint16)),
                n_l2_miss=ft.n_l2_miss.at[fi].add(
                    jnp.where(miss2, u1, 0).astype(jnp.uint16)),
                n_walk=ft.n_walk.at[fi].add(
                    jnp.where(walk_en, u1, 0).astype(jnp.uint16)),
                walk_cyc=ft.walk_cyc.at[fi].add(
                    jnp.where(walk_en, wcyc, 0).astype(jnp.float32)),
                is2m=ft.is2m.at[fi].set(is2m.astype(jnp.uint8)),
            ))
        return st, ()

    return step


def _final_hists(l2):
    """Fold still-resident blocks into the reuse histograms (blocks that
    were never evicted would otherwise be invisible to Figs. 11/24)."""
    bucket = jnp.minimum(l2.reuse, 21)
    is_data = (l2.btype == BT_DATA) & l2.valid
    is_tlb = (l2.btype != BT_DATA) & l2.valid
    hd = l2.hist_reuse_data + jnp.zeros_like(l2.hist_reuse_data).at[
        bucket.reshape(-1)].add(is_data.reshape(-1).astype(jnp.int32))
    ht = l2.hist_reuse_tlb + jnp.zeros_like(l2.hist_reuse_tlb).at[
        bucket.reshape(-1)].add(is_tlb.reshape(-1).astype(jnp.int32))
    return hd, ht


def simulate(cfg: SimConfig, trace: dict) -> Stats:
    """Run one trace under `cfg`; returns (Stats, extras)."""
    step = make_step(cfg)

    @jax.jit
    def run(tr):
        st0 = make_state(cfg)
        st, _ = jax.lax.scan(step, st0, tr)
        hd, ht = _final_hists(st.hier.l2)
        return st.stats, st.hier.n_l2_access, st.hier.n_l2_miss, hd, ht, \
            st.feats, st.pc4

    stats, l2a, l2m, hd, ht, feats, pc4 = run(trace)
    stats = jax.tree.map(lambda x: jax.device_get(x), stats)
    extras = {
        "l2_access": int(l2a), "l2_miss": int(l2m),
        "hist_reuse_data": jax.device_get(hd),
        "hist_reuse_tlb": jax.device_get(ht),
    }
    if cfg.collect:
        extras["feats"] = jax.tree.map(jax.device_get, feats)
        extras["pc4"] = jax.tree.map(jax.device_get, pc4)
    return stats, extras


def simulate_batch(cfg: SimConfig, traces: dict):
    """Run W workloads in lock-step: traces leaves are [T, W, ...].

    One compile + one scan of a vmapped step — on a single CPU core this is
    ~an order of magnitude faster than W sequential runs (SIMD across the
    workload lane, per-step dispatch amortized).
    Returns (Stats [W], extras list of per-workload dicts).
    """
    step = make_step(cfg)
    W = jax.tree.leaves(traces)[0].shape[1]

    @jax.jit
    def run(tr):
        base = make_state(cfg)
        st0 = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (W,) + x.shape), base)
        st, _ = jax.lax.scan(
            lambda ss, acc: (jax.vmap(step)(ss, acc)[0], ()), st0, tr)
        hd, ht = jax.vmap(_final_hists)(st.hier.l2)
        return st.stats, st.hier.n_l2_access, st.hier.n_l2_miss, hd, ht, \
            st.feats, st.pc4

    stats, l2a, l2m, hd, ht, feats, pc4 = run(traces)
    stats = jax.tree.map(jax.device_get, stats)
    extras = []
    for i in range(W):
        e = {"l2_access": int(l2a[i]), "l2_miss": int(l2m[i]),
             "hist_reuse_data": jax.device_get(hd[i]),
             "hist_reuse_tlb": jax.device_get(ht[i])}
        if cfg.collect:
            e["feats"] = jax.tree.map(lambda x: jax.device_get(x[i]), feats)
            e["pc4"] = jax.tree.map(lambda x: jax.device_get(x[i]), pc4)
        extras.append(e)
    per = [jax.tree.map(lambda x: x[i], stats) for i in range(W)]
    return per, extras
