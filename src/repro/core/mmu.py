"""MMU translation-pipeline driver (paper §§4-6, Table 3).

The translation path is a statically composed list of stages (see
``repro.core.stages``): L1 TLB -> L2 TLB -> [Victima L2-cache probe] ->
[hardware L3 TLB] -> [POM-TLB] -> page-table walker (radix or 2-D
nested).  ``make_step`` folds the composition into one scan-step; the
static ``SimConfig`` + composition specialize the compiled code path, so
a jitted ``lax.scan`` simulates ~1M accesses in seconds on CPU exactly
like the pre-pipeline monolith (golden-snapshot tested bit-for-bit).

Three entry points share the step:
  simulate         — one (config, trace)
  simulate_batch   — one config, W workloads in lock-step (vmap)
  simulate_systems — S shape-compatible systems x W workloads in one
                     compiled call (vmap over ``Dyn`` sizing scalars) —
                     how the sweep covers a whole size ladder with a
                     single compilation.

Every entry point runs the access loop through one of two BACKENDS
(``REPRO_SIM_BACKEND`` or the ``backend=`` kwarg):

  scan   — the ``jax.lax.scan`` carry loop described above (default);
  pallas — the same step fused into a blocked Pallas kernel
           (``repro.kernels.mmu_step``) that keeps the state carry
           resident across trace blocks (interpret mode off-TPU).

Both are bit-identical (tests/test_mmu_kernel.py); ``time_shards``
additionally splits the trace time axis into speculative blocks with
exact carry hand-off (``repro.sim.parallel.time_shard_scan``).
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

# a pure pytree/mesh utility with no repro.core (or sim-sibling) imports,
# so this core module can use it without a layering cycle
from repro.sim import parallel
from repro.core.caches import BT_DATA, access_data
from repro.core.stages import (Dyn, Feats, MMUState, Request, STAGES,
                               SimConfig, Stats, WALK_HIST_BUCKETS,
                               default_stages, dramc_of, fill_order,
                               l2_geom_of, make_state, validate_stages)
from repro.core.stages.fold import accum_stats, collect_feats

__all__ = [
    "BACKENDS", "Dyn", "Feats", "MMUState", "SimConfig", "Stats",
    "WALK_HIST_BUCKETS", "make_state", "make_step", "make_systems_runner",
    "resolve_backend", "scan_accesses", "simulate", "simulate_batch",
    "simulate_systems",
]

# access-loop backends: "scan" = lax.scan carry loop, "pallas" = blocked
# resident-state kernel (repro.kernels.mmu_step; interpret mode off-TPU)
BACKENDS = ("scan", "pallas")
_BACKEND_ENV = "REPRO_SIM_BACKEND"


def resolve_backend(backend: str | None = None) -> str:
    """The effective access-loop backend (kwarg > env > "scan").

    Raises ValueError on unknown names so CLI layers can validate BEFORE
    anything compiles (mirroring the sweep's name/tag validation).
    """
    b = backend or os.environ.get(_BACKEND_ENV, "").strip() or "scan"
    if b not in BACKENDS:
        raise ValueError(
            f"unknown simulation backend {b!r} (from "
            f"{'backend=' if backend else _BACKEND_ENV}); "
            f"known: {', '.join(BACKENDS)}")
    return b


def scan_accesses(step, st0, trace, backend: str | None = None,
                  consts=None, block: int | None = None):
    """Run the per-access ``step`` over ``trace`` on the chosen backend.

    Drop-in for ``lax.scan(step, st0, trace)[0]``.  ``step`` takes
    ``(state, access)`` — or ``(state, access, consts)`` when ``consts``
    is given (the pallas kernel cannot close over traced arrays, so
    per-call constants like stacked ladder ``Dyn`` scalars ride as
    explicit inputs on both backends to keep the call shape uniform).
    """
    if resolve_backend(backend) == "scan":
        body = step if consts is None else (
            lambda ss, acc: step(ss, acc, consts))
        st, _ = jax.lax.scan(body, st0, trace)
        return st
    from repro.kernels import mmu_step  # deferred: pallas import is lazy

    return mmu_step.blocked_scan(step, st0, trace, consts=consts,
                                 block=block)


def make_step(cfg: SimConfig, stage_names=None, dyn: Dyn | None = None):
    """Build the scan-step for this configuration.

    Trace record: dict(vpn=int32 4K-VPN, is2m=bool, line=int32 data line
    id, ipa=float32 — per-trace instructions/access so a vmapped batch of
    workloads shares one compiled step).  `dyn` carries traced sizing
    overrides for ladder-batched runs (vmap it alongside the state).
    """
    names = tuple(stage_names) if stage_names else default_stages(cfg)
    validate_stages(cfg, names)
    stages = [STAGES[n] for n in names]
    fills = [STAGES[n] for n in fill_order(names)]
    pressure_thr = jnp.float32(cfg.pressure_mpki)
    bypass_thr = jnp.float32(cfg.bypass_l2mpki)
    geom = l2_geom_of(dyn)  # dynamic L2-cache view (None = static)
    dramc = dramc_of(cfg, dyn)  # DRAM-cache gate (None = compiled out)

    def step(st: MMUState, acc):
        vpn = acc["vpn"]
        is2m = acc["is2m"]
        ipa = acc.get("ipa", jnp.float32(cfg.ipa))
        now = st.now + 1
        st = st._replace(now=now)
        s0 = st.stats

        instrs = jnp.maximum(s0.n_access.astype(jnp.float32), 1.0) * ipa
        pressure = (s0.n_l2tlb_miss.astype(jnp.float32) * 1000.0
                    > pressure_thr * instrs)
        l2_bypass = (st.hier.n_l2_miss.astype(jnp.float32) * 1000.0
                     >= bypass_thr * instrs)
        vpn2 = vpn >> 9
        vpn_sz = jnp.where(is2m, vpn2, vpn)
        req = Request(
            vpn=vpn, is2m=is2m, line=acc["line"], ipa=ipa, vpn2=vpn2,
            vpn_sz=vpn_sz, key2=(vpn_sz << 1) | is2m.astype(jnp.int32),
            now=now, pressure=pressure, l2_bypass=l2_bypass, dyn=dyn,
        )

        # ---------------- lookup pass: fold the composition
        out: dict = {}
        need = jnp.bool_(True)
        trans = jnp.int32(0)   # cycles up to and including the L2 TLB
        past_l2 = jnp.int32(0)  # cycles past the L2 TLB (Fig 9/22/29)
        for stg in stages:
            st, res = stg.lookup(cfg, st, req, need)
            need = need & ~res.hit
            out[stg.name] = res._replace(need=need)
            if stg.past_l2:
                past_l2 = past_l2 + res.cycles
            else:
                trans = trans + res.cycles
        walk_res = out["_walk"] = out[names[-1]]

        # ---------------- fill pass: refills, learning, background walks
        for stg in fills:
            st = stg.fill(cfg, st, req, out)

        # shared-tier port contention (multicore only): accesses that
        # went past the private L2 TLB contend for the shared L3/POM/
        # walker port.  The rotating-slot queue delay is deterministic
        # per (core, now), so vmapped core lanes stay bit-reproducible
        # and independent of lane evaluation order.
        if cfg.n_cores > 1:
            core = acc.get("core", jnp.int32(0))
            slot = (core + now) % jnp.int32(cfg.n_cores)
            q = jnp.int32(cfg.shared_port_cyc) * slot
            past_l2 = past_l2 + jnp.where(out["l2_tlb"].need, q, 0)

        trans = trans + past_l2

        # ---------------- the data access itself
        hier, dcyc = access_data(st.hier, req.line, now, pressure,
                                 cfg.tlb_aware, cfg.lat, geom, dramc)
        st = st._replace(hier=hier)

        st = st._replace(stats=accum_stats(s0, st, out, walk_res,
                                           trans, past_l2, dcyc))
        if cfg.collect:
            st = collect_feats(cfg, st, req, out, walk_res)
        return st, ()

    return step


def _final_hists(l2):
    """Fold still-resident blocks into the reuse histograms (blocks that
    were never evicted would otherwise be invisible to Figs. 11/24)."""
    bucket = jnp.minimum(l2.reuse, 21)
    is_data = (l2.btype == BT_DATA) & l2.valid
    is_tlb = (l2.btype != BT_DATA) & l2.valid
    hd = l2.hist_reuse_data + jnp.zeros_like(l2.hist_reuse_data).at[
        bucket.reshape(-1)].add(is_data.reshape(-1).astype(jnp.int32))
    ht = l2.hist_reuse_tlb + jnp.zeros_like(l2.hist_reuse_tlb).at[
        bucket.reshape(-1)].add(is_tlb.reshape(-1).astype(jnp.int32))
    return hd, ht


def _finalize(st: MMUState, batch_dims: int = 0):
    """Fold a finished state into the per-run output tuple (`batch_dims`
    counts the leading workload/system axes on the state leaves)."""
    hists = _final_hists
    for _ in range(batch_dims):
        hists = jax.vmap(hists)
    hd, ht = hists(st.hier.l2)
    return (st.stats, st.hier.n_l2_access, st.hier.n_l2_miss, hd, ht,
            st.feats, st.pc4,
            (st.hier.n_l3_access, st.hier.n_l3_trans,
             st.hier.n_dramc_access, st.hier.n_dramc_hit))


def _shared_tier_extras(cfg) -> bool:
    """Whether the shared-tier (L3/DRAM-cache) counters surface in extras.
    Gated so single-core extras stay byte-identical to the pre-multicore
    pickles (the sim cache stores extras verbatim)."""
    return (cfg.n_cores > 1 or cfg.dram_cache_sets > 0
            or cfg.shared_tier_stats)


def _extras_of(cfg, l2a, l2m, hd, ht, feats, pc4, shared=None,
               index=lambda x: x):
    e = {"l2_access": int(index(l2a)), "l2_miss": int(index(l2m)),
         "hist_reuse_data": jax.device_get(index(hd)),
         "hist_reuse_tlb": jax.device_get(index(ht))}
    if shared is not None and _shared_tier_extras(cfg):
        e["l3_access"] = int(index(shared[0]))
        e["l3_trans"] = int(index(shared[1]))
        e["dramc_access"] = int(index(shared[2]))
        e["dramc_hit"] = int(index(shared[3]))
    if cfg.collect:
        e["feats"] = jax.tree.map(lambda x: jax.device_get(index(x)), feats)
        e["pc4"] = jax.tree.map(lambda x: jax.device_get(index(x)), pc4)
    return e


def simulate(cfg: SimConfig, trace: dict, stage_names=None,
             backend: str | None = None, block: int | None = None,
             time_shards: int | None = None):
    """Run one trace under `cfg`; returns (Stats, extras).

    ``backend`` selects the access-loop implementation (see BACKENDS),
    ``block`` the pallas trace-block size, and ``time_shards > 1``
    splits the trace time axis into speculative blocks resolved to the
    exact serial carry (``parallel.time_shard_scan``) — all three leave
    the Stats bit-identical to the default scan.
    """
    step = make_step(cfg, stage_names)
    t = int(time_shards or 1)
    if t > 1:
        def body(st, tr):
            return scan_accesses(step, st, tr, backend=backend,
                                 block=block)
        st, _ = parallel.time_shard_scan(
            body, make_state(cfg), trace, t,
            batch="map" if resolve_backend(backend) == "pallas"
            else "vmap")
        outs = jax.jit(_finalize)(st)
    else:
        @jax.jit
        def run(tr):
            st = scan_accesses(step, make_state(cfg), tr,
                               backend=backend, block=block)
            return _finalize(st)

        outs = run(trace)
    stats, l2a, l2m, hd, ht, feats, pc4, shared = outs
    stats = jax.tree.map(lambda x: jax.device_get(x), stats)
    return stats, _extras_of(cfg, l2a, l2m, hd, ht, feats, pc4, shared)


def simulate_batch(cfg: SimConfig, traces: dict, stage_names=None,
                   backend: str | None = None, block: int | None = None):
    """Run W workloads in lock-step: traces leaves are [T, W, ...].

    One compile + one scan of a vmapped step — on a single CPU core this
    is ~an order of magnitude faster than W sequential runs (SIMD across
    the workload lane, per-step dispatch amortized).
    Returns (Stats [W], extras list of per-workload dicts).
    """
    step = make_step(cfg, stage_names)
    W = jax.tree.leaves(traces)[0].shape[1]

    @jax.jit
    def run(tr):
        base = make_state(cfg)
        st0 = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (W,) + x.shape), base)
        st = scan_accesses(
            lambda ss, acc: (jax.vmap(step)(ss, acc)[0], ()), st0, tr,
            backend=backend, block=block)
        return _finalize(st, batch_dims=1)

    stats, l2a, l2m, hd, ht, feats, pc4, shared = run(traces)
    stats = jax.tree.map(jax.device_get, stats)
    extras = [_extras_of(cfg, l2a, l2m, hd, ht, feats, pc4, shared,
                         index=lambda x, i=i: x[i]) for i in range(W)]
    per = [jax.tree.map(lambda x, i=i: x[i], stats) for i in range(W)]
    return per, extras


def _step_sw(cfg: SimConfig, stage_names):
    """S x W-vmapped scan step with the per-system ``Dyn`` scalars
    delivered as ``consts`` — the shape the pallas backend needs (a
    kernel cannot close over traced arrays, so the system vmap moves
    INSIDE the blocked scan instead of wrapping the kernel call)."""

    def step_sw(ss, acc, dyns):
        def per_sys(ss_s, dd):
            step = make_step(cfg, stage_names, dyn=dd)
            return jax.vmap(step)(ss_s, acc)[0]

        return jax.vmap(per_sys)(ss, dyns), ()

    return step_sw


def _broadcast_state(cfg: SimConfig, lead: tuple[int, ...]) -> MMUState:
    base = make_state(cfg)
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x, lead + x.shape), base)


def make_systems_runner(cfg: SimConfig, plan, stage_names=None,
                        backend: str | None = None,
                        block: int | None = None,
                        time_shards: int = 1):
    """Build a REUSABLE sharded S x W dispatch for one mesh plan.

    Returns ``run(dyns, traces) -> (per, extras)``.  The shard_map +
    jit wrapper is constructed once, so same-shape calls — e.g.
    ``runner.run_ladder``'s fixed-width workload chunks — trace, lower
    and compile exactly once instead of once per call.

    ``backend`` picks the access-loop implementation per lane (see
    BACKENDS), ``block`` the pallas trace-block size.  ``time_shards >
    1`` splits the trace time axis into speculative blocks resolved to
    the exact serial carry on a ("t",) device mesh
    (``parallel.time_shard_scan``) — it currently requires a 1x1
    ("sys", "wl") plan (the devices go to the time axis instead).  The
    runner records the last hand-off round count on
    ``run.last_time_shard_info``.
    """
    backend = resolve_backend(backend)
    t_shards = int(time_shards or 1)
    if t_shards > 1 and plan.sys_dim * plan.wl_dim != 1:
        raise ValueError(
            f"time sharding needs a 1x1 ('sys', 'wl') plan (devices go "
            f"to the 't' mesh axis), got {plan.describe()}")

    def run_systems(d, tr):
        # derive the lane width from tr: under shard_map this body sees
        # one [S_blk] x [W_blk] (x [C_blk]) mesh block, not the full grid
        leaf = jax.tree.leaves(tr)[0]
        w_blk = leaf.shape[1]
        # multicore: per-core lanes ([T, W, C] traces) ride the vmapped
        # workload axis — flatten to [T, W*C], un-flatten the outputs so
        # the mesh out_specs see a [S, W, C]-leading grid
        c_blk = leaf.shape[2] if leaf.ndim >= 3 else None
        if c_blk is not None:
            tr = jax.tree.map(
                lambda x: x.reshape((x.shape[0], w_blk * c_blk)
                                    + x.shape[3:]), tr)
        lanes = w_blk if c_blk is None else w_blk * c_blk
        st0 = _broadcast_state(cfg, (lanes,))

        def unflatten(outs):
            if c_blk is None:
                return outs
            return jax.tree.map(
                lambda x: x.reshape(x.shape[:1] + (w_blk, c_blk)
                                    + x.shape[2:]), outs)

        if backend == "scan":
            def one_system(dd):
                step = make_step(cfg, stage_names, dyn=dd)
                st, _ = jax.lax.scan(
                    lambda ss, acc: (jax.vmap(step)(ss, acc)[0], ()),
                    st0, tr)
                return _finalize(st, batch_dims=1)

            return unflatten(jax.vmap(one_system)(d))
        # pallas: the system vmap moves inside the kernel's inner scan
        # (see _step_sw) so the pallas_call itself is never vmapped
        s_blk = jax.tree.leaves(d)[0].shape[0]
        st = scan_accesses(_step_sw(cfg, stage_names),
                           _broadcast_state(cfg, (s_blk, lanes)), tr,
                           backend=backend, consts=d, block=block)
        return unflatten(_finalize(st, batch_dims=2))

    if t_shards <= 1:
        dispatch = parallel.shard_wrap(run_systems, plan)
    else:
        sw = _step_sw(cfg, stage_names)

        def dispatch(dyns, traces):
            S = jax.tree.leaves(dyns)[0].shape[0]
            leaf = jax.tree.leaves(traces)[0]
            W = leaf.shape[1]
            c = leaf.shape[2] if leaf.ndim >= 3 else None
            if c is not None:  # core lanes ride the workload axis
                traces = jax.tree.map(
                    lambda x: x.reshape((x.shape[0], W * c)
                                        + x.shape[3:]), traces)
            lanes = W if c is None else W * c

            def body(st, tr):
                return scan_accesses(sw, st, tr, backend=backend,
                                     consts=dyns, block=block)

            st, info = parallel.time_shard_scan(
                body, _broadcast_state(cfg, (S, lanes)), traces, t_shards,
                batch="map" if backend == "pallas" else "vmap")
            run.last_time_shard_info = info
            outs = jax.jit(_finalize, static_argnames="batch_dims")(
                st, batch_dims=2)
            if c is not None:
                outs = jax.tree.map(
                    lambda x: x.reshape(x.shape[:1] + (W, c)
                                        + x.shape[2:]), outs)
            return outs

    def run(dyns: Dyn, traces: dict):
        S = jax.tree.leaves(dyns)[0].shape[0]
        leaf = jax.tree.leaves(traces)[0]
        W = leaf.shape[1]
        C = leaf.shape[2] if leaf.ndim >= 3 else None
        stats, l2a, l2m, hd, ht, feats, pc4, shared = dispatch(dyns,
                                                               traces)
        stats = jax.tree.map(jax.device_get, stats)
        if C is None:
            per = [[jax.tree.map(lambda x, s=s, w=w: x[s, w], stats)
                    for w in range(W)] for s in range(S)]
            extras = [[_extras_of(cfg, l2a, l2m, hd, ht, feats, pc4,
                                  shared,
                                  index=lambda x, s=s, w=w: x[s, w])
                       for w in range(W)] for s in range(S)]
            return per, extras
        # multicore: per[s][w] / extras[s][w] are per-core lists
        per = [[[jax.tree.map(lambda x, s=s, w=w, k=k: x[s, w, k], stats)
                 for k in range(C)] for w in range(W)] for s in range(S)]
        extras = [[[_extras_of(cfg, l2a, l2m, hd, ht, feats, pc4, shared,
                               index=lambda x, s=s, w=w, k=k: x[s, w, k])
                    for k in range(C)] for w in range(W)]
                  for s in range(S)]
        return per, extras

    run.last_time_shard_info = None
    return run


def simulate_systems(cfg: SimConfig, dyns: Dyn, traces: dict,
                     stage_names=None, plan=None,
                     backend: str | None = None, block: int | None = None,
                     time_shards: int = 1):
    """Run S shape-compatible systems x W workloads in ONE compiled call.

    `cfg` is the ladder's static base config (structures allocated at the
    ladder maximum); `dyns` has [S]-shaped leaves of per-system sizing
    scalars; traces leaves are [T, W, ...] (shared across systems).
    The S x W grid is dispatched over a 2-D ("sys", "wl") device mesh
    via shard_map (repro.sim.parallel): the system axis is padded to a
    mesh multiple (no divisibility precondition) and on a single device
    the 1x1 mesh runs the identical code path as an identity
    partitioning.  `plan` overrides the mesh factorization (see
    ``parallel.plan_mesh``).  ``backend``/``block``/``time_shards``
    forward to ``make_systems_runner``; ``time_shards > 1`` defaults the
    plan to 1x1 (the devices go to the time axis instead).  Returns
    (list[S] of list[W] Stats, extras).  One-shot form of
    ``make_systems_runner`` — callers dispatching the same shapes
    repeatedly should hold on to a runner instead.
    """
    S = jax.tree.leaves(dyns)[0].shape[0]
    W = jax.tree.leaves(traces)[0].shape[1]
    if plan is None:
        plan = (parallel.plan_mesh(S, W, n_devices=1)
                if int(time_shards or 1) > 1 else parallel.plan_mesh(S, W))
    return make_systems_runner(cfg, plan, stage_names, backend=backend,
                               block=block,
                               time_shards=time_shards)(dyns, traces)
