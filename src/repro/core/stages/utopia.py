"""Stage: Utopia — hybrid restrictive/flexible address mapping (PAPERS.md).

Utopia backs most translation-heavy pages with *RestSegs*: set-associative
memory segments whose virtual-to-physical mapping is restrictive, so the
candidate physical frame is computable from the VPN alone and a probe only
has to confirm the tag/permission metadata embedded in the set.  Pages the
RestSegs cannot hold live in the conventionally (flexibly) mapped
*FlexSeg* and fall back to the radix walker (``ptw``/``ptw2d``) — the
walkers are reused unchanged as the FlexSeg path.

The model keeps one RestSeg per page size (4K + 2M), mirroring the pc4/
pc2 counter split.  A probe fetches the set's tag line through the cache
hierarchy (DRAM-row cost when cold, typed as a TLB block so the TLB-aware
SRRIP prioritizes it like POM-TLB lines); a tag match resolves the
translation with NO page walk.  The *migration engine* in ``fill``
promotes costly-to-translate pages into a RestSeg after their demand
walk, reusing the PTW-CP counters — the exact predictor Victima trains —
and a set conflict demotes the LRU resident back to the FlexSeg.

Dyn gating: ``Dyn.utopia_en`` masks the probe's cache traffic, the hit
path and every migration write, so a non-Utopia lane of a batched ladder
is bit-identical to the composition without this stage;
``Dyn.restseg_ways`` runs the RestSeg-associativity sensitivity ladder
through way-masked views (assoc.lookup_dyn/insert_lru_dyn).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.assoc import (insert_lru, insert_lru_dyn, lookup,
                              lookup_dyn)
from repro.core.caches import BT_TLB4, access_pte
from repro.core.page_table import RESTSEG2_BASE, RESTSEG4_BASE
from repro.core.stages.base import (Stage, StageResult, dramc_of,
                                    l2_geom_of, ptwcp_walk_verdict)


class RestSegStage(Stage):
    name = "restseg"

    def lookup(self, cfg, st, req, need):
        uen = None if req.dyn is None else req.dyn.utopia_en
        probe = need if uen is None else need & uen

        # one tag/permission line per set, fetched through the caches
        s4 = req.vpn & (cfg.restseg4_sets - 1)
        s2 = req.vpn2 & (cfg.restseg2_sets - 1)
        tag_line = jnp.where(req.is2m, RESTSEG2_BASE + s2,
                             RESTSEG4_BASE + s4)
        hier, cyc, _ = access_pte(st.hier, tag_line, req.pressure,
                                  cfg.tlb_aware, cfg.lat, probe,
                                  bt=BT_TLB4, geom=l2_geom_of(req.dyn),
                                  dramc=dramc_of(cfg, req.dyn))
        st = st._replace(hier=hier)

        # probe both RestSegs; the access's page size selects the result
        if req.dyn is None:
            h4, w4, i4 = lookup(st.restseg4, req.vpn)
            h2, w2, i2 = lookup(st.restseg2, req.vpn2)
        else:
            h4, w4, i4 = lookup_dyn(st.restseg4, req.vpn,
                                    jnp.int32(cfg.restseg4_sets - 1),
                                    req.dyn.restseg_ways)
            h2, w2, i2 = lookup_dyn(st.restseg2, req.vpn2,
                                    jnp.int32(cfg.restseg2_sets - 1),
                                    req.dyn.restseg_ways)
        hit4 = probe & ~req.is2m & h4
        hit2 = probe & req.is2m & h2
        # LRU touch keeps conflict demotions picking the coldest resident
        rs4 = st.restseg4._replace(meta=st.restseg4.meta.at[i4, w4].set(
            jnp.where(hit4, req.now, st.restseg4.meta[i4, w4])))
        rs2 = st.restseg2._replace(meta=st.restseg2.meta.at[i2, w2].set(
            jnp.where(hit2, req.now, st.restseg2.meta[i2, w2])))
        st = st._replace(restseg4=rs4, restseg2=rs2)

        rhit = hit4 | hit2
        return st, StageResult(hit=rhit, cycles=cyc,
                               info={"probed": probe})

    def fill(self, cfg, st, req, out):
        """Migration engine: promote costly-to-translate pages (§PTW-CP
        verdict after their demand walk) into a RestSeg; a set conflict
        demotes the evicted resident back to the FlexSeg."""
        uen = None if req.dyn is None else req.dyn.utopia_en
        mig = ptwcp_walk_verdict(cfg, st, req,
                                 out["_walk"].info["walk_en"])
        if uen is not None:
            mig = mig & uen
        mig4 = mig & ~req.is2m
        mig2 = mig & req.is2m

        if req.dyn is None:
            rs4, _, conf4 = insert_lru(st.restseg4, req.vpn, req.now, mig4)
            rs2, _, conf2 = insert_lru(st.restseg2, req.vpn2, req.now, mig2)
        else:
            rs4, _, conf4 = insert_lru_dyn(
                st.restseg4, req.vpn, req.now,
                jnp.int32(cfg.restseg4_sets - 1), req.dyn.restseg_ways,
                mig4)
            rs2, _, conf2 = insert_lru_dyn(
                st.restseg2, req.vpn2, req.now,
                jnp.int32(cfg.restseg2_sets - 1), req.dyn.restseg_ways,
                mig2)
        out[self.name].info["n_mig"] = (mig4 | mig2).astype(jnp.int32)
        out[self.name].info["n_conflict"] = (conf4 | conf2).astype(jnp.int32)
        return st._replace(restseg4=rs4, restseg2=rs2)
