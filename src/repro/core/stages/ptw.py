"""Stage: demand page-table walk (native radix / I-SP 1-D shadow walk).

The terminal stage: everything still unresolved walks.  Fill maintains
the PTW-CP per-page counters for non-Victima systems (Victima folds its
counter updates into its own fused fill).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import ptwcp
from repro.core.page_table import walk
from repro.core.stages.base import Stage, StageResult, dramc_of, l2_geom_of


def fill_walk_counters(cfg, st, req, out):
    """PTW-CP counter maintenance for the walked page (non-Victima)."""
    walk_en = out["_walk"].info["walk_en"]
    ndram = out["_walk"].info["ndram"]
    pc4 = ptwcp.update_counters(
        st.pc4, req.vpn & (cfg.n_pages4 - 1), ndram >= 1,
        walk_en & ~req.is2m)
    pc2 = ptwcp.update_counters(
        st.pc2, req.vpn2 & (cfg.n_pages2 - 1), ndram >= 1,
        walk_en & req.is2m)
    return st._replace(pc4=pc4, pc2=pc2)


class RadixWalkStage(Stage):
    name = "ptw"

    def lookup(self, cfg, st, req, need):
        hier, pwcs, wcyc, ndram = walk(
            st.hier, st.pwcs, req.vpn, req.is2m, req.now, req.pressure,
            cfg.tlb_aware, cfg.lat, need, l2_geom_of(req.dyn),
            dramc_of(cfg, req.dyn),
        )
        st = st._replace(hier=hier, pwcs=pwcs)
        info = {
            "walk_en": need, "ndram": ndram,
            "nhost": jnp.int32(0), "n_nt_hit": jnp.int32(0),
            "n_nv_hit": jnp.int32(0),
        }
        return st, StageResult(hit=need, cycles=wcyc, info=info)

    def fill(self, cfg, st, req, out):
        if cfg.victima:
            return st  # VictimaStage.fill owns the counter traffic
        return fill_walk_counters(cfg, st, req, out)
