"""Translation-pipeline contract + shared simulation types.

A *stage* models one level of the address-translation path (L1 TLB,
L2 TLB, Victima L2-cache probe, hardware L3 TLB, POM-TLB, page-table
walker).  Stages obey a uniform contract so ``mmu.make_step`` can fold a
statically composed stage list into one scan step (the composition is
resolved at trace time, so ``lax.scan`` compiles to the same specialized
code path as the old hand-written monolith):

  ``lookup(cfg, state, request, need) -> (state, StageResult)``
      Probe the stage for the accesses still unresolved (`need` mask),
      applying any hit-path state updates (LRU touches, RRPV promotion).
      ``StageResult.hit`` marks accesses this stage resolved and
      ``StageResult.cycles`` the latency it charged.

  ``fill(cfg, state, request, out) -> state``
      Post-walk refill/learning pass (TLB refills, PTW-CP counters,
      Victima block installs).  ``out`` maps stage name -> StageResult
      of the lookup phase; fills may publish derived values into their
      own ``info`` dict for later fills / the stats fold (e.g. the L2
      TLB's evicted entry, consumed by Victima's background walk).

The driver ORs cycles into one of two accumulators selected by the
stage's ``past_l2`` flag: latency before/at the L2 TLB vs. latency past
it (the paper's Figs. 9/22/29 metric).
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import ptwcp
from repro.core.assoc import Assoc, make
from repro.core.caches import Hier, L2Geom, Lat, make_hier
from repro.core.page_table import PWCs, make_pwcs

WALK_HIST_BUCKETS = 64  # 10-cycle buckets for the Fig.4 PTW latency CDF


@dataclasses.dataclass(frozen=True)
class SimConfig:
    """Static simulation configuration (Table 3 defaults)."""

    # --- TLB hierarchy
    l1d4_sets: int = 16   # 64-entry, 4-way (4K pages)
    l1d4_ways: int = 4
    l1d2_sets: int = 8    # 32-entry, 4-way (2M pages)
    l1d2_ways: int = 4
    l1tlb_lat: int = 1
    l2tlb_sets: int = 128  # 1536-entry, 12-way
    l2tlb_ways: int = 12
    l2tlb_lat: int = 12
    # --- optional hardware L3 TLB (0 sets = absent)
    l3tlb_sets: int = 0
    l3tlb_ways: int = 16
    l3tlb_lat: int = 15
    # --- POM-TLB (software L3 TLB resident in memory)
    pom: bool = False
    pom_sets: int = 4096  # 64K entries, 16-way
    pom_ways: int = 16
    # --- Victima
    victima: bool = False
    tlb_aware: bool = True       # TLB-aware SRRIP at the L2 cache
    use_ptwcp: bool = True       # False = insert every candidate (ablation)
    bypass_l2mpki: float = 5.0   # consult PTW-CP only if L2$ MPKI below this
    pressure_mpki: float = 5.0   # "translation pressure" threshold
    # --- Utopia hybrid RestSeg/FlexSeg mapping
    utopia: bool = False
    restseg4_sets: int = 8192    # 4K-page RestSeg: 128K entries, 16-way
    restseg2_sets: int = 256     # 2M-page RestSeg
    restseg_ways: int = 16
    # --- Revelator hash-based speculative translation
    revelator: bool = False
    rev_sets: int = 4096         # signature table: 64K entries, 16-way
    rev_ways: int = 16
    rev_lat: int = 4             # hash + signature probe (near-zero)
    rev_sig_bits: int = 20       # lossy signature width; aliasing between
    #   pages whose hashes share the low rev_sig_bits is the deterministic
    #   stand-in for the paper's frame-allocation mispredictions
    # --- caches
    l1_sets: int = 64
    l1_ways: int = 8
    l2_sets: int = 2048   # 2MB
    l2_ways: int = 16
    l3_sets: int = 2048   # 2MB/core
    l3_ways: int = 16
    lat: Lat = Lat()
    # --- multicore (n_cores=1 is the single-core degenerate case: the
    #     contention model below compiles out entirely, so every existing
    #     system is bit-identical to its pre-multicore self)
    n_cores: int = 1             # independent core lanes (a batch axis:
    #   each core carries its own private L1/L2 TLB + Victima state as a
    #   simulation lane; the shared tier is modeled by static capacity
    #   partitioning — l3/pom/l3tlb/dramc sets divided by n_cores — plus
    #   the rotating shared-port arbitration delay below)
    shared_port_cyc: int = 2     # queueing delay per losing arbitration
    #   slot at the shared tier's port (charged on L2-TLB misses)
    shared_tier_stats: bool = False  # surface shared-L3 / DRAM-cache
    #   occupancy counters in extras (multicore scenario bookkeeping;
    #   off by default so single-core extras stay byte-identical)
    # --- die-stacked DRAM cache below the L3 (0 sets = absent)
    dram_cache_sets: int = 0
    dram_cache_ways: int = 16
    # --- virtualization
    virt: bool = False           # nested paging 2-D walk
    ideal_shadow: bool = False   # I-SP: 1-D shadow walk, free updates
    ntlb_sets: int = 16          # 64-entry nested TLB
    ntlb_ways: int = 4
    # --- bookkeeping
    n_pages4: int = 1 << 21      # 4K-page counter-table entries (masked vpn;
    #   larger footprints alias — counters are advisory predictor state and
    #   XLA-CPU copies of >2M-entry carry arrays dominate sim runtime)
    n_pages2: int = 1 << 14      # 2M-page counter-table entries
    n_pagesh: int = 1 << 14      # host-page counter table (hashed, virt;
    #   small: 10 scatter/gather per virt step — see fused-counter note)
    ipa: float = 3.0             # instructions per traced memory access
    collect: bool = False        # per-page feature collection (Table 2)
    n_feat: int = 1 << 20        # feature-table entries (hashed vpn)


class Dyn(NamedTuple):
    """Traced sizing/latency overrides for ladder-batched simulation.

    A batched sweep allocates structures at the ladder's maximum static
    shape and vmaps the step over these per-system scalars; systems whose
    configs differ only in these fields share one compiled step.
    """

    l2tlb_set_mask: jax.Array  # int32, = live l2tlb sets - 1
    l2tlb_ways: jax.Array      # int32 effective ways
    l2tlb_lat: jax.Array       # int32 probe latency
    l3tlb_lat: jax.Array       # int32 probe latency (unused if no L3 TLB)
    l2_set_mask: jax.Array     # int32, = live L2-cache sets - 1
    l2_ways: jax.Array         # int32 effective L2-cache ways
    victima_en: jax.Array      # bool — Victima stage live on this lane
    #   (lets a radix member ride a victima-composition ladder with the
    #    TLB-block installs and background walks masked off bit-exactly)
    utopia_en: jax.Array       # bool — RestSeg stage live on this lane
    restseg_ways: jax.Array    # int32 effective RestSeg ways
    l3tlb_en: jax.Array        # bool — hardware L3 TLB live on this lane
    pom_en: jax.Array          # bool — POM-TLB live on this lane
    rev_en: jax.Array          # bool — Revelator speculative stage live
    dramc_en: jax.Array        # bool — die-stacked DRAM cache live on
    #   this lane (masks the probe between the L3 and DRAM bit-exactly)


# SimConfig fields a batched ladder may vary across members.  "victima",
# "utopia", "pom", "l3tlb_sets" and "revelator" are special: they are not
# geometry scalars but dyn-*gateable* stage flags (see
# systems.DYN_GATED_STAGES) — lanes lacking the stage mask off all its
# state writes bit-exactly.
DYN_FIELDS = ("l2tlb_sets", "l2tlb_ways", "l2tlb_lat", "l3tlb_lat",
              "l2_sets", "l2_ways", "victima",
              "utopia", "restseg_ways", "l3tlb_sets", "pom", "revelator",
              "dram_cache_sets")


def dyn_of(cfg: SimConfig) -> Dyn:
    """The Dyn scalars equivalent to `cfg`'s static sizing."""
    return Dyn(
        l2tlb_set_mask=jnp.int32(cfg.l2tlb_sets - 1),
        l2tlb_ways=jnp.int32(cfg.l2tlb_ways),
        l2tlb_lat=jnp.int32(cfg.l2tlb_lat),
        l3tlb_lat=jnp.int32(cfg.l3tlb_lat),
        l2_set_mask=jnp.int32(cfg.l2_sets - 1),
        l2_ways=jnp.int32(cfg.l2_ways),
        victima_en=jnp.bool_(cfg.victima),
        utopia_en=jnp.bool_(cfg.utopia),
        restseg_ways=jnp.int32(cfg.restseg_ways),
        l3tlb_en=jnp.bool_(cfg.l3tlb_sets > 0),
        pom_en=jnp.bool_(cfg.pom),
        rev_en=jnp.bool_(cfg.revelator),
        dramc_en=jnp.bool_(cfg.dram_cache_sets > 0),
    )


def l2_geom_of(dyn: "Dyn | None") -> L2Geom | None:
    """The dynamic L2-cache view carried by a request (None = static)."""
    if dyn is None:
        return None
    return L2Geom(set_mask=dyn.l2_set_mask, n_ways=dyn.l2_ways)


def dramc_of(cfg: SimConfig, dyn: "Dyn | None"):
    """The die-stacked DRAM-cache gate for cache-hierarchy accesses.

    ``None`` compiles the probe out entirely — the base config has no
    DRAM cache, so every pre-existing system keeps its exact compiled
    graph.  When the (ladder-maximum) config has one, the gate is a
    traced bool so lanes without it mask the probe off bit-exactly.
    """
    if cfg.dram_cache_sets <= 0:
        return None
    return jnp.bool_(True) if dyn is None else dyn.dramc_en


class Stats(NamedTuple):
    n_access: jax.Array
    n_l1tlb_hit: jax.Array
    n_l2tlb_hit: jax.Array
    n_l2tlb_miss: jax.Array
    n_victima_hit: jax.Array
    n_l3tlb_hit: jax.Array
    n_pom_hit: jax.Array
    n_demand_ptw: jax.Array      # native / guest demand walks
    n_bg_ptw: jax.Array
    n_host_ptw: jax.Array        # virt: demand host walks
    n_ntlb_hit: jax.Array
    n_nvictima_hit: jax.Array    # nested-TLB-block hits in L2 cache
    sum_trans_cyc: jax.Array     # f32
    sum_l2miss_cyc: jax.Array    # f32 — translation cycles past the L2 TLB
    sum_data_cyc: jax.Array      # f32
    sum_walk_cyc: jax.Array      # f32 — demand walk cycles only
    hist_walk: jax.Array         # i32 [WALK_HIST_BUCKETS]
    sum_tlb4_live: jax.Array     # f32 — Σ live TLB blocks (reach, Fig 23)
    sum_tlb2_live: jax.Array     # f32
    # --- Utopia RestSeg (zero for systems without the stage)
    n_restseg_hit: jax.Array      # i32 — probes resolved by a RestSeg
    n_restseg_miss: jax.Array     # i32 — probes that fell through to FlexSeg
    n_restseg_mig: jax.Array      # i32 — pages migrated into a RestSeg
    n_restseg_conflict: jax.Array  # i32 — migrations that demoted a page
    #                                back to FlexSeg (set conflict)
    sum_restseg_cyc: jax.Array    # f32 — Σ RestSeg tag-probe cycles
    hist_restseg: jax.Array       # i32 [WALK_HIST_BUCKETS] — probe-latency
    #                               buckets (same 10-cycle grid as hist_walk)
    # --- Revelator speculation (zero for systems without the stage)
    n_rev_hit: jax.Array          # i32 — correct speculative translations
    n_rev_mispred: jax.Array      # i32 — signature hits that mispredicted
    n_rev_enroll: jax.Array       # i32 — pages enrolled post-walk
    sum_rev_verify_cyc: jax.Array  # f32 — Σ verification-walk cycles
    #                                (overlapped; critical only on mispredict)
    hist_rev_verify: jax.Array    # i32 [WALK_HIST_BUCKETS] — verify-latency
    #                               buckets (same 10-cycle grid as hist_walk)


def zero_stats() -> Stats:
    z = jnp.int32(0)
    f = jnp.float32(0)
    return Stats(
        n_access=z, n_l1tlb_hit=z, n_l2tlb_hit=z, n_l2tlb_miss=z,
        n_victima_hit=z, n_l3tlb_hit=z, n_pom_hit=z, n_demand_ptw=z,
        n_bg_ptw=z, n_host_ptw=z, n_ntlb_hit=z, n_nvictima_hit=z,
        sum_trans_cyc=f, sum_l2miss_cyc=f, sum_data_cyc=f, sum_walk_cyc=f,
        hist_walk=jnp.zeros((WALK_HIST_BUCKETS,), jnp.int32),
        sum_tlb4_live=f, sum_tlb2_live=f,
        n_restseg_hit=z, n_restseg_miss=z, n_restseg_mig=z,
        n_restseg_conflict=z, sum_restseg_cyc=f,
        hist_restseg=jnp.zeros((WALK_HIST_BUCKETS,), jnp.int32),
        n_rev_hit=z, n_rev_mispred=z, n_rev_enroll=z,
        sum_rev_verify_cyc=f,
        hist_rev_verify=jnp.zeros((WALK_HIST_BUCKETS,), jnp.int32),
    )


class Feats(NamedTuple):
    """Per-page features for the Table-2 predictor study (hashed table)."""
    n_access: jax.Array     # uint16
    n_l1_miss: jax.Array    # uint16
    n_l2_miss: jax.Array    # uint16 — L2 TLB misses
    n_walk: jax.Array       # uint16 — unsaturated walk count
    walk_cyc: jax.Array     # float32 — Σ demand-walk cycles (label source)
    is2m: jax.Array         # uint8


def zero_feats(n: int) -> Feats:
    return Feats(
        n_access=jnp.zeros((n,), jnp.uint16),
        n_l1_miss=jnp.zeros((n,), jnp.uint16),
        n_l2_miss=jnp.zeros((n,), jnp.uint16),
        n_walk=jnp.zeros((n,), jnp.uint16),
        walk_cyc=jnp.zeros((n,), jnp.float32),
        is2m=jnp.zeros((n,), jnp.uint8),
    )


class RevTable(NamedTuple):
    """Revelator signature table: hashed VPN -> speculative frame.

    ``tab`` is keyed by a *lossy* multiplicative-hash signature of the
    size-tagged page id (so distinct pages can alias — the deterministic
    misprediction source); ``vpn`` shadows the enrolled page id per way,
    the ground truth the verification walk confirms against.
    """

    tab: Assoc       # tags = lossy signature, meta = LRU stamp
    vpn: jax.Array   # int32 [S, W] — enrolled key2 per way


def make_rev(n_sets: int, n_ways: int) -> RevTable:
    return RevTable(tab=make(n_sets, n_ways),
                    vpn=jnp.zeros((n_sets, n_ways), jnp.int32))


class MMUState(NamedTuple):
    now: jax.Array
    l1d4: Assoc
    l1d2: Assoc
    l2tlb: Assoc
    l3tlb: Assoc
    pom: Assoc
    pwcs: PWCs
    hier: Hier
    ntlb: Assoc
    restseg4: Assoc  # Utopia 4K-page RestSeg (tags = migrated vpn)
    restseg2: Assoc  # Utopia 2M-page RestSeg (tags = migrated vpn2)
    rev: RevTable    # Revelator signature table (sized 1 when off)
    pc4: ptwcp.PageCounters
    pc2: ptwcp.PageCounters
    pch: ptwcp.PageCounters
    feats: Feats
    stats: Stats


def make_state(cfg: SimConfig) -> MMUState:
    return MMUState(
        now=jnp.int32(0),
        l1d4=make(cfg.l1d4_sets, cfg.l1d4_ways),
        l1d2=make(cfg.l1d2_sets, cfg.l1d2_ways),
        l2tlb=make(cfg.l2tlb_sets, cfg.l2tlb_ways),
        l3tlb=make(max(cfg.l3tlb_sets, 1), cfg.l3tlb_ways),
        pom=make(cfg.pom_sets if cfg.pom else 1, cfg.pom_ways),
        pwcs=make_pwcs(),
        hier=make_hier(cfg.l1_sets, cfg.l1_ways, cfg.l2_sets, cfg.l2_ways,
                       cfg.l3_sets, cfg.l3_ways,
                       max(cfg.dram_cache_sets, 1), cfg.dram_cache_ways),
        ntlb=make(cfg.ntlb_sets if cfg.virt else 1, cfg.ntlb_ways),
        restseg4=make(cfg.restseg4_sets if cfg.utopia else 1,
                      cfg.restseg_ways if cfg.utopia else 1),
        restseg2=make(cfg.restseg2_sets if cfg.utopia else 1,
                      cfg.restseg_ways if cfg.utopia else 1),
        rev=make_rev(cfg.rev_sets if cfg.revelator else 1,
                     cfg.rev_ways if cfg.revelator else 1),
        pc4=ptwcp.make_counters(cfg.n_pages4),
        pc2=ptwcp.make_counters(cfg.n_pages2),
        pch=ptwcp.make_counters(cfg.n_pagesh if cfg.virt else 1),
        feats=zero_feats(cfg.n_feat if cfg.collect else 1),
        stats=zero_stats(),
    )


class Request(NamedTuple):
    """One traced access plus derived keys and step-global signals."""

    vpn: jax.Array       # int32 4K-page vpn
    is2m: jax.Array      # bool — access lands in a 2M-backed region
    line: jax.Array      # int32 data line id
    ipa: jax.Array       # f32 instructions per access
    vpn2: jax.Array      # vpn >> 9 (2M-page id)
    vpn_sz: jax.Array    # size-native page id
    key2: jax.Array      # unified L2 TLB key (page id + size bit)
    now: jax.Array       # logical time (LRU stamp)
    pressure: jax.Array  # bool — translation pressure (L2-TLB MPKI > thr)
    l2_bypass: jax.Array  # bool — L2$ MPKI high: bypass the PTW-CP
    dyn: Dyn | None      # ladder-batched sizing overrides (None = static)


class StageResult(NamedTuple):
    hit: jax.Array            # bool — accesses resolved by this stage
    cycles: jax.Array         # int32 — latency charged by this stage
    info: dict                # stage-specific values for fills/stats
    #                           (fills may publish into their own dict)
    need: Any = None          # bool — still-unresolved mask AFTER this
    #                           stage (filled in by the driver)


def ptwcp_walk_verdict(cfg: SimConfig, st: "MMUState", req: "Request",
                       walk_en):
    """Post-walk PTW-CP verdict shared by fill-time promotion engines
    (Utopia's RestSeg migration, Revelator's enrollment).

    Reads the *freshly trained* counters — callers run after whichever
    fill owns the counter traffic (see ``stages.fill_order``) — and
    applies the standard overrides: ``use_ptwcp=False`` promotes every
    candidate, high L2$ MPKI (``req.l2_bypass``) bypasses the predictor.
    """
    idx4 = req.vpn & (cfg.n_pages4 - 1)
    idx2 = req.vpn2 & (cfg.n_pages2 - 1)
    pred = jnp.where(req.is2m,
                     ptwcp.predict_page(st.pc2, idx2),
                     ptwcp.predict_page(st.pc4, idx4))
    pred = pred if cfg.use_ptwcp else jnp.bool_(True)
    return walk_en & (pred | req.l2_bypass)


class Stage:
    """Base stage: a no-op level.  Subclasses override lookup/fill."""

    name: str = "?"
    past_l2: bool = True  # cycles count toward the past-L2-TLB metric

    def lookup(self, cfg: SimConfig, st: MMUState, req: Request, need):
        return st, StageResult(hit=jnp.bool_(False), cycles=jnp.int32(0),
                               info={})

    def fill(self, cfg: SimConfig, st: MMUState, req: Request,
             out: dict) -> MMUState:
        return st


def hash_h(x: jax.Array, n: int) -> jax.Array:
    """Fibonacci-ish hash for the host-page counter table."""
    return (x * jnp.int32(-1640531535)) & (n - 1)
