"""Stage: unified L2 TLB (size-tagged keys, LRU).

Supports ladder-batched sizing: when the request carries ``Dyn`` scalars
the probe/refill run against a dynamically sized view of the allocated
structure (see assoc.lookup_dyn), so one compiled step serves the whole
L2-TLB size ladder under vmap.  The refill publishes the evicted entry
into its ``info`` — POM-TLB learning and Victima's eviction-triggered
background walk consume it.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.assoc import insert_lru, insert_lru_dyn, lookup, lookup_dyn
from repro.core.stages.base import Stage, StageResult


class L2TLBStage(Stage):
    name = "l2_tlb"
    past_l2 = False

    def lookup(self, cfg, st, req, need):
        if req.dyn is None:
            ht, wt, stt = lookup(st.l2tlb, req.key2)
            lat = cfg.l2tlb_lat
        else:
            ht, wt, stt = lookup_dyn(st.l2tlb, req.key2,
                                     req.dyn.l2tlb_set_mask,
                                     req.dyn.l2tlb_ways)
            lat = req.dyn.l2tlb_lat
        hit = need & ht
        l2tlb = st.l2tlb._replace(meta=st.l2tlb.meta.at[stt, wt].set(
            jnp.where(hit, req.now, st.l2tlb.meta[stt, wt])))
        st = st._replace(l2tlb=l2tlb)
        return st, StageResult(hit=hit, cycles=jnp.where(need, lat, 0),
                               info={})

    def fill(self, cfg, st, req, out):
        miss2 = out[self.name].need
        if req.dyn is None:
            l2tlb2, ev_tag, ev_valid = insert_lru(
                st.l2tlb, req.key2, req.now, miss2)
        else:
            l2tlb2, ev_tag, ev_valid = insert_lru_dyn(
                st.l2tlb, req.key2, req.now, req.dyn.l2tlb_set_mask,
                req.dyn.l2tlb_ways, miss2)
        out[self.name].info["ev_tag"] = ev_tag
        out[self.name].info["ev_valid"] = ev_valid
        return st._replace(l2tlb=l2tlb2)
