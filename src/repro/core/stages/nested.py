"""Stage: nested-paging 2-D page-table walk (virtualized, paper §9.3).

Every guest-PT access first resolves its own gPA -> hPA through the
nested TLB, optionally Victima's nested-TLB blocks in the L2 cache, and
finally a 4-level host walk.  The data page's own gPA is translated
last (identity gPA map: gpn = vpn).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import ptwcp
from repro.core.assoc import insert_lru, lookup
from repro.core.caches import (BT_NTLB, access_pte, l2_lookup,
                               l2_retag_to_tlb, l2_touch)
from repro.core.page_table import (PWC_LAT, PWCs, _level_lines_2m,
                                   _level_lines_4k, host_walk)
from repro.core.stages.base import (Stage, StageResult, dramc_of, hash_h,
                                    l2_geom_of)
from repro.core.stages.ptw import fill_walk_counters


def nested_translate(cfg, st, gpn, pressure, l2_bypass, enable,
                     geom=None, ven=None, dramc=None):
    """gPA-page -> hPA (virt.): nested TLB -> [Victima nested-TLB block] ->
    host walk.  Returns (st, cycles, host_walked, ntlb_hit, nvictima_hit).

    `geom` is the dynamic L2-cache view for ladder-batched runs; `ven`
    (None = static) gates the Victima nested-TLB-block machinery per
    lane, bit-exactly reproducing a plain-NP system when off."""
    en = jnp.asarray(enable)
    hit_n, w_n, s_n = lookup(st.ntlb, gpn)
    ntlb = st.ntlb._replace(
        meta=st.ntlb.meta.at[s_n, w_n].set(
            jnp.where(en & hit_n, st.now, st.ntlb.meta[s_n, w_n])
        )
    )
    st = st._replace(ntlb=ntlb)

    miss = en & ~hit_n
    cycles = jnp.where(en, 1, 0)  # 1-cycle nested TLB

    # Victima: probe L2 cache for a nested TLB block
    if cfg.victima:
        vh, vw, vs = l2_lookup(st.hier.l2, gpn >> 3, BT_NTLB, geom)
        vhit = miss & vh
        if ven is not None:
            vhit = vhit & ven
        l2c = l2_touch(st.hier.l2, vs, vw, pressure, cfg.tlb_aware, vhit)
        st = st._replace(hier=st.hier._replace(l2=l2c))
        cycles = cycles + jnp.where(vhit, cfg.lat.l2, 0)
    else:
        vhit = jnp.bool_(False)

    need_walk = miss & ~vhit
    hier, wc, ndram, _leaf = host_walk(
        st.hier, gpn, pressure, cfg.tlb_aware, cfg.lat, need_walk, geom,
        dramc,
    )
    st = st._replace(hier=hier)
    cycles = cycles + wc

    # host-page PTW-CP counters + nested-TLB-block insertion
    hidx = hash_h(gpn, cfg.n_pagesh)
    pch = ptwcp.update_counters(st.pch, hidx, ndram >= 1, need_walk)
    st = st._replace(pch=pch)
    if cfg.victima:
        pred = ptwcp.predict_page(pch, hidx) if cfg.use_ptwcp \
            else jnp.bool_(True)
        ins = need_walk & (pred | l2_bypass)
        if ven is not None:
            ins = ins & ven
        l2c = l2_retag_to_tlb(st.hier.l2, gpn >> 3, BT_NTLB, pressure,
                              cfg.tlb_aware, ins, geom)
        st = st._replace(hier=st.hier._replace(l2=l2c))

    # refill nested TLB; evicted nested entry triggers background host walk
    ntlb2, ev_tag, ev_valid = insert_lru(st.ntlb, gpn, st.now, miss)
    st = st._replace(ntlb=ntlb2)
    if cfg.victima:
        eidx = hash_h(ev_tag, cfg.n_pagesh)
        epred = ptwcp.predict_page(st.pch, eidx) if cfg.use_ptwcp \
            else jnp.bool_(True)
        bg = miss & ev_valid & (epred | l2_bypass)
        if ven is not None:
            bg = bg & ven
        hier, _, bdram, _ = host_walk(st.hier, ev_tag, pressure,
                                      cfg.tlb_aware, cfg.lat, bg, geom,
                                      dramc)
        pch = ptwcp.update_counters(st.pch, eidx, bdram >= 1, bg)
        l2c = l2_retag_to_tlb(hier.l2, ev_tag >> 3, BT_NTLB, pressure,
                              cfg.tlb_aware, bg, geom)
        st = st._replace(hier=hier._replace(l2=l2c), pch=pch)

    return st, cycles, need_walk, en & hit_n, vhit


def guest_walk_2d(cfg, st, vpn, is2m, pressure, l2_bypass, enable,
                  geom=None, ven=None, dramc=None):
    """Nested-paging 2-D walk: every guest-PT access first resolves its own
    gPA->hPA via ``nested_translate``.  Returns (st, cycles, n_dram,
    n_host_walks, n_ntlb_hits, n_nvictima_hits)."""
    en = jnp.asarray(enable)
    vpn2 = vpn >> 9
    l4k = _level_lines_4k(vpn)
    l2m = _level_lines_2m(vpn2)
    lines = [
        jnp.where(is2m, l2m[0], l4k[0]),
        jnp.where(is2m, l2m[1], l4k[1]),
        jnp.where(is2m, l2m[2], l4k[2]),
        l4k[3],
    ]
    n_levels = jnp.where(is2m, 3, 4)

    k_pml4 = jnp.where(is2m, vpn2 >> 18, vpn >> 27)
    k_pdp = jnp.where(is2m, vpn2 >> 9, vpn >> 18)
    k_pd = vpn >> 9
    hit4, _, _ = lookup(st.pwcs.pml4, k_pml4)
    hit3, _, _ = lookup(st.pwcs.pdp, k_pdp)
    hit2, _, _ = lookup(st.pwcs.pd, k_pd)
    hit2 = hit2 & ~is2m
    start = jnp.where(hit2, 3, jnp.where(hit3, 2, jnp.where(hit4, 1, 0)))
    start = jnp.where(is2m, jnp.minimum(start, 2), start)

    cycles = jnp.where(en, jnp.int32(PWC_LAT), 0)
    n_dram = jnp.int32(0)
    n_host = jnp.int32(0)
    n_nt_hit = jnp.int32(0)
    n_nv_hit = jnp.int32(0)
    for slot in range(4):
        slot_en = en & (slot >= start) & (slot < n_levels)
        # translate the guest-PT line's gPA page first
        st, ncyc, walked, nth, nvh = nested_translate(
            cfg, st, lines[slot] >> 6, pressure, l2_bypass, slot_en,
            geom, ven, dramc,
        )
        n_host = n_host + (walked & slot_en).astype(jnp.int32)
        n_nt_hit = n_nt_hit + nth.astype(jnp.int32)
        n_nv_hit = n_nv_hit + nvh.astype(jnp.int32)
        hier, c, d = access_pte(st.hier, lines[slot], pressure,
                                cfg.tlb_aware, cfg.lat, slot_en, geom=geom,
                                dramc=dramc)
        st = st._replace(hier=hier)
        cycles = cycles + ncyc + c
        n_dram = n_dram + d.astype(jnp.int32)

    p4, _, _ = insert_lru(st.pwcs.pml4, k_pml4, st.now, en & (start <= 0))
    p3, _, _ = insert_lru(st.pwcs.pdp, k_pdp, st.now, en & (start <= 1))
    p2, _, _ = insert_lru(st.pwcs.pd, k_pd, st.now,
                          en & (start <= 2) & ~is2m)
    st = st._replace(pwcs=PWCs(pml4=p4, pdp=p3, pd=p2))

    # finally translate the data page's own gPA (gpn = vpn, identity map)
    st, ncyc, walked, nth, nvh = nested_translate(
        cfg, st, vpn, pressure, l2_bypass, en, geom, ven, dramc)
    n_host = n_host + (walked & en).astype(jnp.int32)
    n_nt_hit = n_nt_hit + nth.astype(jnp.int32)
    n_nv_hit = n_nv_hit + nvh.astype(jnp.int32)
    return st, cycles + ncyc, n_dram, n_host, n_nt_hit, n_nv_hit


class NestedWalkStage(Stage):
    name = "ptw2d"

    def lookup(self, cfg, st, req, need):
        ven = None if req.dyn is None else req.dyn.victima_en
        st, wcyc, ndram, nhost, n_nt_hit, n_nv_hit = guest_walk_2d(
            cfg, st, req.vpn, req.is2m, req.pressure, req.l2_bypass, need,
            l2_geom_of(req.dyn), ven, dramc_of(cfg, req.dyn),
        )
        info = {
            "walk_en": need, "ndram": ndram, "nhost": nhost,
            "n_nt_hit": n_nt_hit, "n_nv_hit": n_nv_hit,
        }
        return st, StageResult(hit=need, cycles=wcyc, info=info)

    def fill(self, cfg, st, req, out):
        if cfg.victima:
            return st  # VictimaStage.fill owns the counter traffic
        return fill_walk_counters(cfg, st, req, out)
