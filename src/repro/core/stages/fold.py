"""Per-step folds over the pipeline's stage results: Stats accumulation
and the Table-2 per-page feature stream."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.stages.base import (Feats, MMUState, Stats,
                                    WALK_HIST_BUCKETS, hash_h)


def _hit32(out, name):
    return out[name].hit.astype(jnp.int32) if name in out else jnp.int32(0)


def accum_stats(s0: Stats, st: MMUState, out, walk_res, trans, past_l2,
                dcyc) -> Stats:
    miss2 = out["l2_tlb"].need
    walk_en = walk_res.info["walk_en"]
    wcyc = walk_res.cycles
    n_bg = out["victima"].info["n_bg"] if "victima" in out else jnp.int32(0)
    bucket = jnp.minimum(wcyc // 10, WALK_HIST_BUCKETS - 1)
    l2 = st.hier.l2
    if "restseg" in out:
        rs = out["restseg"]
        rs_probed = rs.info["probed"]
        rs_hit = rs.hit
        rs_cyc = rs.cycles
        rs_mig = rs.info["n_mig"]
        rs_conf = rs.info["n_conflict"]
    else:
        rs_probed = rs_hit = jnp.bool_(False)
        rs_mig = rs_conf = rs_cyc = jnp.int32(0)
    rs_bucket = jnp.minimum(rs_cyc // 10, WALK_HIST_BUCKETS - 1)
    if "rev" in out:
        rv = out["rev"]
        rv_hit = rv.hit
        rv_correct = rv.info["correct"]
        rv_mispred = rv.info["mispred"]
        rv_enroll = rv.info["n_enroll"]
        rv_vcyc = rv.info["verify_cyc"]
    else:
        rv_hit = rv_correct = rv_mispred = jnp.bool_(False)
        rv_enroll = rv_vcyc = jnp.int32(0)
    rv_bucket = jnp.minimum(rv_vcyc // 10, WALK_HIST_BUCKETS - 1)
    return Stats(
        n_access=s0.n_access + 1,
        n_l1tlb_hit=s0.n_l1tlb_hit + _hit32(out, "l1_tlb"),
        n_l2tlb_hit=s0.n_l2tlb_hit + _hit32(out, "l2_tlb"),
        n_l2tlb_miss=s0.n_l2tlb_miss + miss2.astype(jnp.int32),
        n_victima_hit=s0.n_victima_hit + _hit32(out, "victima"),
        n_l3tlb_hit=s0.n_l3tlb_hit + _hit32(out, "l3_tlb"),
        n_pom_hit=s0.n_pom_hit + _hit32(out, "pom"),
        n_demand_ptw=s0.n_demand_ptw + walk_en.astype(jnp.int32),
        n_bg_ptw=s0.n_bg_ptw + n_bg,
        n_host_ptw=s0.n_host_ptw + walk_res.info["nhost"],
        n_ntlb_hit=s0.n_ntlb_hit + walk_res.info["n_nt_hit"],
        n_nvictima_hit=s0.n_nvictima_hit + walk_res.info["n_nv_hit"],
        sum_trans_cyc=s0.sum_trans_cyc + trans.astype(jnp.float32),
        sum_l2miss_cyc=s0.sum_l2miss_cyc
        + jnp.where(miss2, past_l2, 0).astype(jnp.float32),
        sum_data_cyc=s0.sum_data_cyc + dcyc.astype(jnp.float32),
        sum_walk_cyc=s0.sum_walk_cyc
        + jnp.where(walk_en, wcyc, 0).astype(jnp.float32),
        hist_walk=s0.hist_walk.at[bucket].add(walk_en.astype(jnp.int32)),
        sum_tlb4_live=s0.sum_tlb4_live + l2.n_tlb4.astype(jnp.float32),
        sum_tlb2_live=s0.sum_tlb2_live + l2.n_tlb2.astype(jnp.float32),
        n_restseg_hit=s0.n_restseg_hit + rs_hit.astype(jnp.int32),
        n_restseg_miss=s0.n_restseg_miss
        + (rs_probed & ~rs_hit).astype(jnp.int32),
        n_restseg_mig=s0.n_restseg_mig + rs_mig,
        n_restseg_conflict=s0.n_restseg_conflict + rs_conf,
        sum_restseg_cyc=s0.sum_restseg_cyc + rs_cyc.astype(jnp.float32),
        hist_restseg=s0.hist_restseg.at[rs_bucket].add(
            rs_probed.astype(jnp.int32)),
        n_rev_hit=s0.n_rev_hit + rv_correct.astype(jnp.int32),
        n_rev_mispred=s0.n_rev_mispred + rv_mispred.astype(jnp.int32),
        n_rev_enroll=s0.n_rev_enroll + rv_enroll,
        sum_rev_verify_cyc=s0.sum_rev_verify_cyc
        + rv_vcyc.astype(jnp.float32),
        hist_rev_verify=s0.hist_rev_verify.at[rv_bucket].add(
            rv_hit.astype(jnp.int32)),
    )


def collect_feats(cfg, st: MMUState, req, out, walk_res) -> MMUState:
    """Table-2 per-page feature stream (hashed table)."""
    miss1 = out["l1_tlb"].need
    miss2 = out["l2_tlb"].need
    walk_en = walk_res.info["walk_en"]
    wcyc = walk_res.cycles
    fi = hash_h(req.vpn_sz, cfg.n_feat)
    ft = st.feats
    u1 = jnp.uint16(1)
    return st._replace(feats=Feats(
        n_access=ft.n_access.at[fi].add(u1),
        n_l1_miss=ft.n_l1_miss.at[fi].add(
            jnp.where(miss1, u1, 0).astype(jnp.uint16)),
        n_l2_miss=ft.n_l2_miss.at[fi].add(
            jnp.where(miss2, u1, 0).astype(jnp.uint16)),
        n_walk=ft.n_walk.at[fi].add(
            jnp.where(walk_en, u1, 0).astype(jnp.uint16)),
        walk_cyc=ft.walk_cyc.at[fi].add(
            jnp.where(walk_en, wcyc, 0).astype(jnp.float32)),
        is2m=ft.is2m.at[fi].set(req.is2m.astype(jnp.uint8)),
    ))
