"""Stage: optional hardware L3 TLB (probe latency swept in Fig. 8)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.assoc import insert_lru, lookup
from repro.core.stages.base import Stage, StageResult


class L3TLBStage(Stage):
    name = "l3_tlb"

    def lookup(self, cfg, st, req, need):
        lat = cfg.l3tlb_lat if req.dyn is None else req.dyn.l3tlb_lat
        # dyn gate: a ladder lane without a hardware L3 TLB neither pays
        # the probe latency nor touches the (never-filled) structure
        len_ = None if req.dyn is None else req.dyn.l3tlb_en
        probe = need if len_ is None else need & len_
        h3, w3, s3 = lookup(st.l3tlb, req.key2)
        l3hit = probe & h3
        l3tlb = st.l3tlb._replace(meta=st.l3tlb.meta.at[s3, w3].set(
            jnp.where(l3hit, req.now, st.l3tlb.meta[s3, w3])))
        st = st._replace(l3tlb=l3tlb)
        # probe latency is paid by every access that reaches this level
        return st, StageResult(hit=l3hit, cycles=jnp.where(probe, lat, 0),
                               info={})

    def fill(self, cfg, st, req, out):
        walk_en = out["_walk"].info["walk_en"]
        if req.dyn is not None:
            walk_en = walk_en & req.dyn.l3tlb_en
        l3t, _, _ = insert_lru(st.l3tlb, req.key2, req.now, walk_en)
        return st._replace(l3tlb=l3t)
