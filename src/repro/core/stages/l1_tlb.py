"""Stage: split L1 D-TLBs (64-entry 4K + 32-entry 2M, LRU)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.assoc import insert_lru, lookup
from repro.core.stages.base import Stage, StageResult


class L1TLBStage(Stage):
    name = "l1_tlb"
    past_l2 = False

    def lookup(self, cfg, st, req, need):
        h4, w4, s4 = lookup(st.l1d4, req.vpn)
        h2, w2, s2 = lookup(st.l1d2, req.vpn2)
        hit1 = jnp.where(req.is2m, h2, h4)
        l1d4 = st.l1d4._replace(meta=st.l1d4.meta.at[s4, w4].set(
            jnp.where(h4 & ~req.is2m, req.now, st.l1d4.meta[s4, w4])))
        l1d2 = st.l1d2._replace(meta=st.l1d2.meta.at[s2, w2].set(
            jnp.where(h2 & req.is2m, req.now, st.l1d2.meta[s2, w2])))
        st = st._replace(l1d4=l1d4, l1d2=l1d2)
        return st, StageResult(hit=hit1, cycles=jnp.int32(cfg.l1tlb_lat),
                               info={})

    def fill(self, cfg, st, req, out):
        miss1 = out[self.name].need
        l1d4b, _, _ = insert_lru(st.l1d4, req.vpn, req.now,
                                 miss1 & ~req.is2m)
        l1d2b, _, _ = insert_lru(st.l1d2, req.vpn2, req.now,
                                 miss1 & req.is2m)
        return st._replace(l1d4=l1d4b, l1d2=l1d2b)
