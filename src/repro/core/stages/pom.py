"""Stage: POM-TLB — software-managed L3 TLB resident in memory.

Entries are fetched through the cache hierarchy (typed as TLB blocks so
the TLB-aware SRRIP prioritizes them, per Table 3); hit/miss bookkeeping
is tracked by a shadow associative structure.  Fill learns both the
demand-walked entry and the L2 TLB's evicted entry.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.assoc import insert_lru, lookup
from repro.core.caches import BT_TLB4, access_pte
from repro.core.page_table import POM_BASE
from repro.core.stages.base import Stage, StageResult, dramc_of, l2_geom_of


class POMStage(Stage):
    name = "pom"

    def lookup(self, cfg, st, req, need):
        # dyn gate: a non-POM ladder lane must not fetch POM lines through
        # the caches (access_pte mutates L2/L3) nor probe the shadow assoc
        pen = None if req.dyn is None else req.dyn.pom_en
        probe = need if pen is None else need & pen
        pom_line = POM_BASE + (
            (req.key2 & ((cfg.pom_sets * cfg.pom_ways) - 1)) >> 2)
        hier, pc_cyc, _ = access_pte(
            st.hier, pom_line, req.pressure, cfg.tlb_aware, cfg.lat,
            probe, bt=BT_TLB4, geom=l2_geom_of(req.dyn),
            dramc=dramc_of(cfg, req.dyn),
        )
        st = st._replace(hier=hier)
        hp, wp, sp = lookup(st.pom, req.key2)
        pomhit = probe & hp
        pom = st.pom._replace(meta=st.pom.meta.at[sp, wp].set(
            jnp.where(pomhit, req.now, st.pom.meta[sp, wp])))
        st = st._replace(pom=pom)
        return st, StageResult(hit=pomhit, cycles=pc_cyc, info={})

    def fill(self, cfg, st, req, out):
        walk_en = out["_walk"].info["walk_en"]
        miss2 = out["l2_tlb"].need
        ev_tag = out["l2_tlb"].info["ev_tag"]
        ev_valid = out["l2_tlb"].info["ev_valid"]
        if req.dyn is not None:
            walk_en = walk_en & req.dyn.pom_en
            ev_valid = ev_valid & req.dyn.pom_en
        pom2, _, _ = insert_lru(st.pom, req.key2, req.now, walk_en)
        pom2, _, _ = insert_lru(pom2, ev_tag, req.now, miss2 & ev_valid)
        return st._replace(pom=pom2)
