"""Composable translation-pipeline stages.

``STAGES`` maps stage names to singleton stage objects; a *composition*
is an ordered tuple of names ending in a walker stage ("ptw" or
"ptw2d").  ``default_stages(cfg)`` derives the canonical composition
from a SimConfig; the system registry (repro.sim.systems) declares each
evaluated system's composition explicitly and is validated against it.
"""
from __future__ import annotations

from repro.core.stages.base import (DYN_FIELDS, Dyn, Feats, MMUState,
                                    Request, SimConfig, Stage, StageResult,
                                    Stats, WALK_HIST_BUCKETS, dramc_of,
                                    dyn_of, l2_geom_of, make_state,
                                    zero_feats, zero_stats)
from repro.core.stages.l1_tlb import L1TLBStage
from repro.core.stages.l2_tlb import L2TLBStage
from repro.core.stages.l3_tlb import L3TLBStage
from repro.core.stages.nested import NestedWalkStage
from repro.core.stages.pom import POMStage
from repro.core.stages.ptw import RadixWalkStage
from repro.core.stages.revelator import RevelatorStage
from repro.core.stages.utopia import RestSegStage
from repro.core.stages.victima import VictimaStage

STAGES: dict[str, Stage] = {
    s.name: s for s in (
        L1TLBStage(), L2TLBStage(), RevelatorStage(), VictimaStage(),
        L3TLBStage(), POMStage(), RestSegStage(), RadixWalkStage(),
        NestedWalkStage(),
    )
}

WALK_STAGES = ("ptw", "ptw2d")


def default_stages(cfg: SimConfig) -> tuple[str, ...]:
    """Canonical stage composition implied by a SimConfig."""
    names = ["l1_tlb", "l2_tlb"]
    if cfg.revelator:
        names.append("rev")  # speculate right at the L2-TLB miss: a
        #   correct prediction hides every later level AND the walk
    if cfg.victima:
        names.append("victima")
    if cfg.l3tlb_sets > 0:
        names.append("l3_tlb")
    if cfg.pom:
        names.append("pom")
    if cfg.utopia:
        names.append("restseg")  # last resort before the FlexSeg walk
    names.append("ptw2d" if cfg.virt and not cfg.ideal_shadow else "ptw")
    return tuple(names)


def validate_stages(cfg: SimConfig, names: tuple[str, ...]) -> None:
    """A composition must agree with the config flags the stages read."""
    expect = default_stages(cfg)
    if tuple(names) != expect:
        raise ValueError(
            f"stage composition {names} inconsistent with config "
            f"(expected {expect}: the rev/victima/l3/pom/utopia/virt "
            f"flags and the stage list must agree)")


def fill_order(names: tuple[str, ...]) -> tuple[str, ...]:
    """Refill/learning pass order for a composition.

    Victima systems: the L2 TLB refill's evicted entry feeds Victima's
    background walk, so it must land first.  Non-Victima systems update
    the walker's PTW-CP counters then refill the L2 TLB.  Utopia's
    migration engine reads the post-walk PTW-CP counters, so it runs
    right after whichever of those owns the counter traffic.  POM /
    L3-TLB learning and the L1 refill close out every composition.
    """
    walker = names[-1]
    order = ["l2_tlb", "victima"] if "victima" in names \
        else [walker, "l2_tlb"]
    if "restseg" in names:
        order.append("restseg")
    if "rev" in names:
        order.append("rev")  # enrollment reads post-walk counters too
    order += [n for n in ("pom", "l3_tlb") if n in names]
    order.append("l1_tlb")
    return tuple(order)


__all__ = [
    "DYN_FIELDS", "Dyn", "Feats", "MMUState", "Request", "STAGES",
    "SimConfig", "Stage", "StageResult", "Stats", "WALK_HIST_BUCKETS",
    "WALK_STAGES", "default_stages", "dramc_of", "dyn_of", "fill_order",
    "l2_geom_of", "make_state", "validate_stages", "zero_feats",
    "zero_stats",
]
