"""Stage: Revelator — hash-based speculative address translation
(PAPERS.md, arXiv 2508.02007).

Revelator attacks PTW latency from the opposite side of Victima/Utopia:
instead of enlarging translation reach, it *predicts* the translation.
System software enrolls pages into a hash-based speculative mapping; on
an L2-TLB miss the core hashes the VPN, probes a small signature table
at low fixed latency (``rev_lat``), and — on a signature hit — fetches
data with the predicted frame immediately while the regular page-table
walk *verifies* the prediction off the critical path.  A correct
prediction hides the entire walk (the access pays only the probe); a
misprediction is discovered when the verification walk completes, so
the access effectively waits the overlapped walk cost after all.

Model mapping onto the pipeline contract (the RestSeg probe-then-
fallback shape is the template, but with verify-later accounting):

  lookup — hash ``key2`` to a *lossy* signature, probe the table.  A
      signature hit resolves the translation (both correct predictions
      AND mispredictions: the verification walk itself produces the
      right translation), so downstream stages and the demand walker
      are skipped.  The verification walk runs here with
      ``enable=sig_hit`` — real cache/PT traffic, cycles accounted in
      ``Stats.sum_rev_verify_cyc`` — but only a mispredict puts those
      cycles on the critical path.  Aliasing between pages whose hashes
      share the low ``rev_sig_bits`` is the deterministic stand-in for
      the paper's frame-allocation conflicts; verification repairs the
      aliased entry in place.  Signature misses fall through to the
      composition's existing walkers untouched.

  fill — enrollment is PTW-CP-guided exactly like Utopia's migration
      engine: after a demand walk (which implies the page was NOT in
      the live table), the freshly trained counters decide whether the
      page is costly enough to enroll.

Dyn gating: ``Dyn.rev_en`` masks the probe, the verification walk and
every table write, so a non-Revelator lane of a batched ladder is
bit-identical to the composition without this stage.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.assoc import Assoc, lru_victim, set_index
from repro.core.page_table import walk
from repro.core.stages.base import (RevTable, Stage, StageResult,
                                    dramc_of, l2_geom_of,
                                    ptwcp_walk_verdict)
from repro.core.stages.nested import guest_walk_2d


def rev_sig(key2, bits: int):
    """Lossy multiplicative-hash signature of a size-tagged page id."""
    return (key2 * jnp.int32(-1640531535)) & jnp.int32((1 << bits) - 1)


def _rev_insert(rev: RevTable, sig, key2, now, enable) -> RevTable:
    """``insert_lru`` plus the shadow enrolled-page write (same way)."""
    tab = rev.tab
    s = set_index(sig, tab.n_sets)
    w = lru_victim(tab, s)
    en = jnp.asarray(enable)
    new_tab = Assoc(
        tags=tab.tags.at[s, w].set(jnp.where(en, sig, tab.tags[s, w])),
        valid=tab.valid.at[s, w].set(jnp.where(en, True, tab.valid[s, w])),
        meta=tab.meta.at[s, w].set(jnp.where(en, now, tab.meta[s, w])),
    )
    return RevTable(tab=new_tab, vpn=rev.vpn.at[s, w].set(
        jnp.where(en, key2, rev.vpn[s, w])))


class RevelatorStage(Stage):
    name = "rev"

    def lookup(self, cfg, st, req, need):
        ren = None if req.dyn is None else req.dyn.rev_en
        probe = need if ren is None else need & ren
        geom = l2_geom_of(req.dyn)

        sig = rev_sig(req.key2, cfg.rev_sig_bits)
        tab = st.rev.tab
        s = set_index(sig, tab.n_sets)
        row_hits = tab.valid[s] & (tab.tags[s] == sig)
        w = jnp.argmax(row_hits)
        sig_hit = probe & jnp.any(row_hits)
        # a lossy-signature hit whose enrolled page differs is the
        # misprediction: the speculative frame belonged to the alias
        correct = sig_hit & (st.rev.vpn[s, w] == req.key2)
        mispred = sig_hit & ~correct

        # LRU touch + in-place repair (verification rewrites the aliased
        # entry with the walked translation; no-op on correct hits)
        rev = RevTable(
            tab=tab._replace(meta=tab.meta.at[s, w].set(
                jnp.where(sig_hit, req.now, tab.meta[s, w]))),
            vpn=st.rev.vpn.at[s, w].set(
                jnp.where(sig_hit, req.key2, st.rev.vpn[s, w])))
        st = st._replace(rev=rev)

        # verification walk — real PT/cache traffic, off the critical
        # path unless the prediction was wrong
        if cfg.virt and not cfg.ideal_shadow:
            ven = None if req.dyn is None else req.dyn.victima_en
            st, vcyc, _, _, _, _ = guest_walk_2d(
                cfg, st, req.vpn, req.is2m, req.pressure, req.l2_bypass,
                sig_hit, geom, ven, dramc_of(cfg, req.dyn))
        else:
            hier, pwcs, vcyc, _ = walk(
                st.hier, st.pwcs, req.vpn, req.is2m, req.now,
                req.pressure, cfg.tlb_aware, cfg.lat, sig_hit, geom,
                dramc_of(cfg, req.dyn))
            st = st._replace(hier=hier, pwcs=pwcs)
        vcyc = jnp.where(sig_hit, vcyc, 0)

        cycles = jnp.where(sig_hit,
                           cfg.rev_lat + jnp.where(mispred, vcyc, 0), 0)
        return st, StageResult(hit=sig_hit, cycles=cycles,
                               info={"probed": probe, "correct": correct,
                                     "mispred": mispred,
                                     "verify_cyc": vcyc})

    def fill(self, cfg, st, req, out):
        """PTW-CP-guided enrollment: after a demand walk, the freshly
        trained counters (this fill runs after the walker's / Victima's
        counter updates — see stages.fill_order) decide whether the
        walked page is costly enough to enroll in the signature table."""
        ren = None if req.dyn is None else req.dyn.rev_en
        enroll = ptwcp_walk_verdict(cfg, st, req,
                                    out["_walk"].info["walk_en"])
        if ren is not None:
            enroll = enroll & ren

        sig = rev_sig(req.key2, cfg.rev_sig_bits)
        st = st._replace(rev=_rev_insert(st.rev, sig, req.key2, req.now,
                                         enroll))
        out[self.name].info["n_enroll"] = enroll.astype(jnp.int32)
        return st
