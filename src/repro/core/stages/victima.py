"""Stage: Victima — TLB blocks living in the L2 cache (paper §5).

Lookup probes the L2 cache for a typed TLB block covering the missing
page's 8-page region.  Fill implements the PTW-CP-gated install of the
demand walk's leaf PTEs plus the eviction-triggered background walk that
re-homes entries evicted from the L2 TLB (paper §5.2).  All counter
traffic is fused into ONE gather + ONE scatter per table so the XLA CPU
backend keeps the (multi-MB) tables in place across the scan.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import ptwcp
from repro.core.caches import (BT_TLB2, BT_TLB4, l2_lookup, l2_retag_to_tlb,
                               l2_touch)
from repro.core.page_table import walk
from repro.core.stages.base import Stage, StageResult, dramc_of, l2_geom_of


class VictimaStage(Stage):
    name = "victima"

    def lookup(self, cfg, st, req, need):
        geom = l2_geom_of(req.dyn)
        # ladder lanes with victima_en=False never install TLB blocks, so
        # their probes can never hit; the gate still masks the touch for
        # defense in depth (None = static run, gate compiled away)
        ven = None if req.dyn is None else req.dyn.victima_en
        vkey = jnp.where(req.is2m, req.vpn2 >> 3, req.vpn >> 3)
        vbt = jnp.where(req.is2m, BT_TLB2, BT_TLB4)
        # typed lookup (btype must match)
        vh, vwy, sset = l2_lookup(st.hier.l2, vkey, vbt, geom)
        vhit = need & vh
        if ven is not None:
            vhit = vhit & ven
        l2c = l2_touch(st.hier.l2, sset, vwy, req.pressure, cfg.tlb_aware,
                       vhit)
        st = st._replace(hier=st.hier._replace(l2=l2c))
        return st, StageResult(hit=vhit,
                               cycles=jnp.where(vhit, cfg.lat.l2, 0),
                               info={"vkey": vkey, "vbt": vbt})

    def fill(self, cfg, st, req, out):
        geom = l2_geom_of(req.dyn)
        ven = None if req.dyn is None else req.dyn.victima_en
        walk_res = out["_walk"]
        walk_en = walk_res.info["walk_en"]
        ndram = walk_res.info["ndram"]
        miss2 = out["l2_tlb"].need
        ev_tag = out["l2_tlb"].info["ev_tag"]
        ev_valid = out["l2_tlb"].info["ev_valid"]
        vkey = out[self.name].info["vkey"]
        vbt = out[self.name].info["vbt"]
        now, is2m = req.now, req.is2m

        ev_vpn = ev_tag >> 1
        ev2m = (ev_tag & 1).astype(jnp.bool_)
        bg_vpn4 = jnp.where(ev2m, ev_vpn << 9, ev_vpn)

        # counter slot 1 (the background-walk slot): when this lane's
        # victima gate is off it must reproduce the walker's plain
        # fill_walk_counters bit-for-bit, so the slot is redirected onto
        # the demand index (both slots then scatter the same updated
        # value — equivalent to the single-index update)
        d4, b4 = req.vpn & (cfg.n_pages4 - 1), bg_vpn4 & (cfg.n_pages4 - 1)
        d2, b2 = req.vpn2 & (cfg.n_pages2 - 1), ev_vpn & (cfg.n_pages2 - 1)
        if ven is not None:
            b4 = jnp.where(ven, b4, d4)
            b2 = jnp.where(ven, b2, d2)
        i4 = jnp.stack([d4, b4])
        i2 = jnp.stack([d2, b2])
        f4, c4 = st.pc4.freq[i4].astype(jnp.int32), \
            st.pc4.cost[i4].astype(jnp.int32)
        f2, c2 = st.pc2.freq[i2].astype(jnp.int32), \
            st.pc2.cost[i2].astype(jnp.int32)

        # demand prediction on post-walk counters (computed analytically)
        fpost = jnp.where(is2m, f2[0], f4[0]) + walk_en.astype(jnp.int32)
        cpost = jnp.where(is2m, c2[0], c4[0]) \
            + (walk_en & (ndram >= 1)).astype(jnp.int32)
        pred = ptwcp.predict(jnp.minimum(fpost, ptwcp.FREQ_MAX),
                             jnp.minimum(cpost, ptwcp.COST_MAX))
        pred = pred if cfg.use_ptwcp else jnp.bool_(True)
        ins = walk_en & (pred | req.l2_bypass)
        if ven is not None:
            ins = ins & ven
        l2c = l2_retag_to_tlb(st.hier.l2, vkey, vbt, req.pressure,
                              cfg.tlb_aware, ins, geom)
        st = st._replace(hier=st.hier._replace(l2=l2c))

        # eviction-triggered background walk + TLB-block install
        fe = jnp.where(ev2m, f2[1], f4[1])
        ce = jnp.where(ev2m, c2[1], c4[1])
        epred = ptwcp.predict(fe, ce)
        epred = epred if cfg.use_ptwcp else jnp.bool_(True)
        bg = miss2 & ev_valid & (epred | req.l2_bypass)
        if ven is not None:
            bg = bg & ven
        hier, pwcs, _, bdram = walk(
            st.hier, st.pwcs, bg_vpn4, ev2m, now, req.pressure,
            cfg.tlb_aware, cfg.lat, bg, geom, dramc_of(cfg, req.dyn),
        )
        ebt = jnp.where(ev2m, BT_TLB2, BT_TLB4)
        l2c = l2_retag_to_tlb(hier.l2, ev_vpn >> 3, ebt, req.pressure,
                              cfg.tlb_aware, bg, geom)
        st = st._replace(hier=hier._replace(l2=l2c), pwcs=pwcs)
        out[self.name].info["n_bg"] = bg.astype(jnp.int32)

        # fused saturating counter writeback (2 slots per table)
        en4 = jnp.stack([walk_en & ~is2m, bg & ~ev2m])
        en2 = jnp.stack([walk_en & is2m, bg & ev2m])
        dr = jnp.stack([ndram >= 1, bdram >= 1])
        nf4 = jnp.minimum(f4 + en4, ptwcp.FREQ_MAX)
        nc4 = jnp.minimum(c4 + (en4 & dr), ptwcp.COST_MAX)
        nf2 = jnp.minimum(f2 + en2, ptwcp.FREQ_MAX)
        nc2 = jnp.minimum(c2 + (en2 & dr), ptwcp.COST_MAX)
        if ven is not None:
            # gate off: slot 1 aliases slot 0, so it must carry slot 0's
            # updated value (a stale duplicate write would win the scatter)
            nf4 = nf4.at[1].set(jnp.where(ven, nf4[1], nf4[0]))
            nc4 = nc4.at[1].set(jnp.where(ven, nc4[1], nc4[0]))
            nf2 = nf2.at[1].set(jnp.where(ven, nf2[1], nf2[0]))
            nc2 = nc2.at[1].set(jnp.where(ven, nc2[1], nc2[0]))
        return st._replace(
            pc4=ptwcp.PageCounters(
                freq=st.pc4.freq.at[i4].set(nf4.astype(jnp.uint8)),
                cost=st.pc4.cost.at[i4].set(nc4.astype(jnp.uint8))),
            pc2=ptwcp.PageCounters(
                freq=st.pc2.freq.at[i2].set(nf2.astype(jnp.uint8)),
                cost=st.pc2.cost.at[i2].set(nc2.astype(jnp.uint8))),
        )
