"""Cache hierarchy model: L1D (LRU), L2 (SRRIP + Victima TLB blocks), L3 (SRRIP).

The L2 cache is the structure Victima modifies (§5.1 of the paper): each
block carries a *block type* —

    BT_DATA = 0   conventional data block (tag = physical line id)
    BT_TLB4 = 1   TLB block, 8 PTEs for 8 contiguous 4K pages (tag = vpn>>3)
    BT_TLB2 = 2   TLB block for 2M pages                      (tag = vpn2m>>3)
    BT_NTLB = 3   nested TLB block (virt.), 8 host leaf PTEs  (tag = gpn>>3)

Tag matching always requires the block type to match, which models the
paper's TLB-entry bit + disjoint tag layout.  Reuse histograms (paper
Figs. 11 & 24) and live TLB-block counts (Fig. 23 translation reach) are
folded into the cache state and updated on insert/evict.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.assoc import (
    RRIP_MAX,
    Assoc,
    insert_lru,
    lookup,
    make,
    set_index,
    srrip_age_and_pick,
    srrip_victim_tlb_aware,
    touch_lru,
)

BT_DATA, BT_TLB4, BT_TLB2, BT_NTLB = 0, 1, 2, 3
REUSE_BUCKETS = 22  # reuse counts 0..20, bucket 21 = ">20" overflow


class L2Geom(NamedTuple):
    """Traced view geometry of a dynamically sized L2 cache.

    A ladder-batched run allocates the L2 at the ladder's maximum static
    shape; each member's live geometry is a set mask plus an effective
    way count.  Because every insert masks its set index and restricts
    victim selection to ways below ``n_ways``, the view is bit-identical
    to a statically allocated (live_sets, n_ways) cache — the invariant
    the ladder-equivalence tests pin.  ``geom=None`` everywhere below
    selects the static path (identical compiled code to pre-Dyn days).
    """

    set_mask: jax.Array  # int32 = live sets - 1
    n_ways: jax.Array    # int32 effective ways


def _l2_set(l2: "L2Cache", key: jax.Array, geom: L2Geom | None):
    return set_index(key, l2.n_sets) if geom is None else key & geom.set_mask


def _way_ok(l2: "L2Cache", geom: L2Geom | None):
    if geom is None:
        return None
    return jnp.arange(l2.tags.shape[1]) < geom.n_ways


class L2Cache(NamedTuple):
    tags: jax.Array    # int32 [S, W]
    valid: jax.Array   # bool  [S, W]
    rrpv: jax.Array    # int32 [S, W]
    btype: jax.Array   # int32 [S, W]
    reuse: jax.Array   # int32 [S, W]
    hist_reuse_data: jax.Array  # int32 [REUSE_BUCKETS] — filled on eviction
    hist_reuse_tlb: jax.Array   # int32 [REUSE_BUCKETS]
    n_tlb4: jax.Array  # int32 scalar — live TLB blocks (4K)
    n_tlb2: jax.Array  # int32 scalar — live TLB blocks (2M)
    n_ntlb: jax.Array  # int32 scalar — live nested TLB blocks

    @property
    def n_sets(self) -> int:
        return self.tags.shape[0]


def make_l2(n_sets: int, n_ways: int) -> L2Cache:
    z = jnp.zeros((n_sets, n_ways), jnp.int32)
    return L2Cache(
        tags=z,
        valid=jnp.zeros((n_sets, n_ways), jnp.bool_),
        rrpv=z,
        btype=z,
        reuse=z,
        hist_reuse_data=jnp.zeros((REUSE_BUCKETS,), jnp.int32),
        hist_reuse_tlb=jnp.zeros((REUSE_BUCKETS,), jnp.int32),
        n_tlb4=jnp.int32(0),
        n_tlb2=jnp.int32(0),
        n_ntlb=jnp.int32(0),
    )


def l2_lookup(l2: L2Cache, key: jax.Array, btype,
              geom: L2Geom | None = None):
    s = _l2_set(l2, key, geom)
    # no way mask needed on probe: inserts never touch ways past the
    # view's limit, so those ways are never valid
    hits = l2.valid[s] & (l2.tags[s] == key) & (l2.btype[s] == btype)
    return jnp.any(hits), jnp.argmax(hits), s


def l2_touch(
    l2: L2Cache,
    s: jax.Array,
    w: jax.Array,
    pressure: jax.Array,
    tlb_aware: bool,
    enable,
) -> L2Cache:
    """Hit-promotion per paper Listing 1 `updateOnL2CacheHit`.

    TLB blocks under pressure decrement RRPV by 3, everything else by 1.
    Reuse counter increments (for Figs. 11/24).
    """
    en = jnp.asarray(enable)
    is_tlbish = l2.btype[s, w] != BT_DATA
    dec = jnp.where(is_tlbish & pressure & tlb_aware, 3, 1)
    new_rrpv = jnp.maximum(l2.rrpv[s, w] - dec, 0)
    return l2._replace(
        rrpv=l2.rrpv.at[s, w].set(jnp.where(en, new_rrpv, l2.rrpv[s, w])),
        reuse=l2.reuse.at[s, w].set(l2.reuse[s, w] + en.astype(jnp.int32)),
    )


def _account_evict(l2: L2Cache, s, w, evicting) -> L2Cache:
    """Histogram + live-count bookkeeping for the block being replaced."""
    bt = l2.btype[s, w]
    was_valid = l2.valid[s, w] & evicting
    bucket = jnp.minimum(l2.reuse[s, w], REUSE_BUCKETS - 1)
    is_data = bt == BT_DATA
    one = jnp.int32(1)
    hist_d = l2.hist_reuse_data.at[bucket].add(
        jnp.where(was_valid & is_data, one, 0)
    )
    hist_t = l2.hist_reuse_tlb.at[bucket].add(
        jnp.where(was_valid & ~is_data, one, 0)
    )
    dec = was_valid.astype(jnp.int32)
    return l2._replace(
        hist_reuse_data=hist_d,
        hist_reuse_tlb=hist_t,
        n_tlb4=l2.n_tlb4 - jnp.where(bt == BT_TLB4, dec, 0),
        n_tlb2=l2.n_tlb2 - jnp.where(bt == BT_TLB2, dec, 0),
        n_ntlb=l2.n_ntlb - jnp.where(bt == BT_NTLB, dec, 0),
    )


def l2_insert(
    l2: L2Cache,
    key: jax.Array,
    btype,
    pressure: jax.Array,
    tlb_aware: bool,
    enable,
    geom: L2Geom | None = None,
) -> L2Cache:
    """Insert a block (Listing 1 `insertBlockInL2` + victim selection).

    Inserted TLB blocks under pressure get RRPV=0; everything else the
    standard SRRIP long re-reference interval (RRIP_MAX-1).
    Evicted TLB blocks are dropped (paper §5.1).
    """
    en = jnp.asarray(enable)
    btype = jnp.asarray(btype, jnp.int32)
    s = _l2_set(l2, key, geom)
    way_ok = _way_ok(l2, geom)
    row_rrpv, row_valid = l2.rrpv[s], l2.valid[s]
    row_is_tlb = l2.btype[s] != BT_DATA
    if tlb_aware:
        aged, w = srrip_victim_tlb_aware(row_rrpv, row_valid, row_is_tlb,
                                         pressure, way_ok)
    else:
        aged, w = srrip_age_and_pick(row_rrpv, row_valid, way_ok)

    l2 = _account_evict(l2, s, w, en)
    ins_is_tlbish = btype != BT_DATA
    ins_rrpv = jnp.where(ins_is_tlbish & pressure & tlb_aware, 0, RRIP_MAX - 1)
    aged = aged.at[w].set(ins_rrpv)
    inc = en.astype(jnp.int32)
    return l2._replace(
        tags=l2.tags.at[s, w].set(jnp.where(en, key, l2.tags[s, w])),
        valid=l2.valid.at[s, w].set(l2.valid[s, w] | en),
        rrpv=l2.rrpv.at[s].set(jnp.where(en, aged, l2.rrpv[s])),
        btype=l2.btype.at[s, w].set(jnp.where(en, btype, l2.btype[s, w])),
        reuse=l2.reuse.at[s, w].set(jnp.where(en, 0, l2.reuse[s, w])),
        n_tlb4=l2.n_tlb4 + jnp.where(btype == BT_TLB4, inc, 0),
        n_tlb2=l2.n_tlb2 + jnp.where(btype == BT_TLB2, inc, 0),
        n_ntlb=l2.n_ntlb + jnp.where(btype == BT_NTLB, inc, 0),
    )


def l2_retag_to_tlb(
    l2: L2Cache,
    key: jax.Array,
    btype,
    pressure: jax.Array,
    tlb_aware: bool,
    enable,
    geom: L2Geom | None = None,
) -> L2Cache:
    """Victima §5.2: transform the cache line holding the fetched leaf PTEs
    into a TLB block, *unless* one already exists for this region.

    (The physical line was inserted by the walk's PTE fetch; lookup by VA
    requires the block to live in set(VA), so the transformation is modeled
    as an insert at set(key) — behaviourally identical.)
    """
    # check for an existing TLB block of this region+type (§5.2 step 2)
    s = _l2_set(l2, key, geom)
    btype_arr = jnp.asarray(btype, jnp.int32)
    exists = jnp.any(
        l2.valid[s] & (l2.tags[s] == key) & (l2.btype[s] == btype_arr)
    )
    return l2_insert(
        l2, key, btype, pressure, tlb_aware,
        jnp.asarray(enable) & ~exists, geom,
    )


# ---------------------------------------------------------------- L3 (SRRIP)


def l3_access(l3: Assoc, key: jax.Array, enable):
    """Probe L3; fill on miss. Returns (l3, hit)."""
    en = jnp.asarray(enable)
    hit, w, s = lookup(l3, key)
    # hit: promote to RRPV 0
    meta_hit = l3.meta.at[s, w].set(jnp.where(hit & en, 0, l3.meta[s, w]))
    l3 = l3._replace(meta=meta_hit)
    # miss: insert with SRRIP
    aged, vw = srrip_age_and_pick(l3.meta[s], l3.valid[s])
    do_ins = en & ~hit
    aged = aged.at[vw].set(RRIP_MAX - 1)
    l3 = Assoc(
        tags=l3.tags.at[s, vw].set(jnp.where(do_ins, key, l3.tags[s, vw])),
        valid=l3.valid.at[s, vw].set(l3.valid[s, vw] | do_ins),
        meta=l3.meta.at[s].set(jnp.where(do_ins, aged, l3.meta[s])),
    )
    return l3, hit


# ---------------------------------------------------------------- hierarchy


class Hier(NamedTuple):
    l1d: Assoc
    l2: L2Cache
    l3: Assoc
    dramc: Assoc            # die-stacked DRAM cache (sized 1 when off)
    # running counters for MPKI-style signals
    n_l2_access: jax.Array  # int32 — demand data accesses reaching L2
    n_l2_miss: jax.Array    # int32
    # shared-tier occupancy counters (multicore scenario bookkeeping)
    n_l3_access: jax.Array   # int32 — demand probes reaching the L3
    n_l3_trans: jax.Array    # int32 — of those, translation-typed
    #                          (walker PTE / TLB-block / POM traffic)
    n_dramc_access: jax.Array  # int32 — L3 misses probing the DRAM cache
    n_dramc_hit: jax.Array     # int32


def make_hier(l1_sets=64, l1_ways=8, l2_sets=2048, l2_ways=16,
              l3_sets=2048, l3_ways=16,
              dramc_sets=1, dramc_ways=16) -> Hier:
    z = jnp.int32(0)
    return Hier(
        l1d=make(l1_sets, l1_ways),
        l2=make_l2(l2_sets, l2_ways),
        l3=make(l3_sets, l3_ways),
        dramc=make(dramc_sets, dramc_ways),
        n_l2_access=z,
        n_l2_miss=z,
        n_l3_access=z,
        n_l3_trans=z,
        n_dramc_access=z,
        n_dramc_hit=z,
    )


class Lat(NamedTuple):
    """Latency constants (cycles), Table 3 + calibration."""

    l1d: int = 4
    l2: int = 16
    l3: int = 35
    dram: int = 160  # full DRAM round trip (beyond L3 probe)
    dramc: int = 58  # die-stacked DRAM-cache hit (beyond L3 probe) —
    #   in-package DRAM, roughly a third of the off-package round trip


def _dramc_probe(h: Hier, line: jax.Array, miss3, lat: Lat, dramc):
    """Probe the die-stacked DRAM cache on an L3 miss (SRRIP, same
    policy as the L3 — it is an ``Assoc`` driven by ``l3_access``).

    ``dramc`` is the live gate (see ``stages.base.dramc_of``): ``None``
    compiles the probe out and this reduces to the plain DRAM path; a
    traced ``False`` masks it off bit-exactly (the miss cost folds back
    to exactly ``lat.dram``).  Returns (h, miss_cyc, dram) where
    ``miss_cyc`` is the beyond-L3 cycle term and ``dram`` the accesses
    that still went to main memory.
    """
    if dramc is None:
        return h, jnp.int32(lat.dram), miss3
    gate = jnp.asarray(dramc) & miss3
    dcc, hitd = l3_access(h.dramc, line, gate)
    h = h._replace(
        dramc=dcc,
        n_dramc_access=h.n_dramc_access + gate.astype(jnp.int32),
        n_dramc_hit=h.n_dramc_hit + (gate & hitd).astype(jnp.int32),
    )
    dram = miss3 & ~(gate & hitd)
    miss_cyc = jnp.where(
        gate, jnp.int32(lat.dramc) + jnp.where(hitd, 0, lat.dram),
        jnp.int32(lat.dram))
    return h, miss_cyc, dram


def access_data(h: Hier, line: jax.Array, now: jax.Array,
                pressure: jax.Array, tlb_aware: bool, lat: Lat,
                geom: L2Geom | None = None, dramc=None):
    """Demand data access L1D→L2→L3→[DRAM cache]→DRAM with fills.
    Returns (h, cycles).  ``dramc`` gates the die-stacked DRAM-cache
    probe (None = absent, compiled out)."""
    hit1, w1, s1 = lookup(h.l1d, line)
    h = h._replace(l1d=touch_lru(h.l1d, s1, w1, now))

    hit2, w2, s2 = l2_lookup(h.l2, line, BT_DATA, geom)
    go_l2 = ~hit1
    l2c = l2_touch(h.l2, s2, w2, pressure, tlb_aware, go_l2 & hit2)

    go_l3 = go_l2 & ~hit2
    l3c, hit3 = l3_access(h.l3, line, go_l3)
    # fill L2 on L2 miss (from L3 or DRAM)
    l2c = l2_insert(l2c, line, BT_DATA, pressure, tlb_aware, go_l3, geom)
    # stream prefetcher at L2 (Table 3): next-line fill on L2 miss.
    # This is what keeps PT/PTE lines from squatting in the L2 under
    # data-intensive streams (PTW latencies match the paper's Fig. 4).
    nxt = line + 1
    pf_hit, _, _ = l2_lookup(l2c, nxt, BT_DATA, geom)
    l2c = l2_insert(l2c, nxt, BT_DATA, pressure, tlb_aware,
                    go_l3 & ~pf_hit, geom)
    # fill L1D on any L1 miss
    l1c, _, _ = insert_lru(h.l1d, line, now, go_l2)

    # background traffic: the traced stream is one data line per access,
    # but a real core also moves code/stack/auxiliary-heap lines through
    # L2/L3 between traced accesses.  Without it, hot PT lines squat in
    # the L2 forever and baseline PTWs are unrealistically cheap (the
    # paper measures ≈137-cycle PTWs, Fig. 4).  Two pseudo-random
    # untracked lines per access reproduce that pressure; Victima's
    # TLB blocks survive it through the TLB-aware policy — which is the
    # paper's §5.1 motivation verbatim.
    for salt in (jnp.int32(-1640531527), jnp.int32(-2048144789)):
        bg_line = ((now * jnp.int32(-1640531527)) ^ salt) & ((1 << 26) - 1)
        l3c, bg_hit3 = l3_access(l3c, bg_line, True)
        l2c = l2_insert(l2c, bg_line, BT_DATA, pressure, tlb_aware,
                        ~bg_hit3, geom)

    # die-stacked DRAM cache between the L3 and main memory (background
    # lines model pressure only — they never charge latency, so they
    # skip the probe)
    h, miss_cyc, _dram = _dramc_probe(
        h._replace(l3=l3c), line, go_l3 & ~hit3, lat, dramc)

    cycles = jnp.where(
        hit1, lat.l1d,
        jnp.where(hit2, lat.l2, jnp.where(hit3, lat.l3, lat.l3 + miss_cyc)),
    )
    h = h._replace(
        l1d=l1c,
        l2=l2c,
        n_l2_access=h.n_l2_access + go_l2.astype(jnp.int32),
        n_l2_miss=h.n_l2_miss + (go_l3).astype(jnp.int32),
        n_l3_access=h.n_l3_access + go_l3.astype(jnp.int32),
    )
    return h, cycles


def access_pte(h: Hier, line: jax.Array, pressure: jax.Array,
               tlb_aware: bool, lat: Lat, enable, bt: int = BT_DATA,
               geom: L2Geom | None = None, dramc=None):
    """Page-table-walker access (starts at L2). Returns (h, cycles, dram).

    `bt` lets POM-TLB lines be typed as TLB blocks so the TLB-aware SRRIP
    prioritizes them (Table 3: POM-TLB uses the §5.1 policy).  ``dramc``
    gates the die-stacked DRAM-cache probe between the L3 and main
    memory (None = absent, compiled out); a DRAM-cache hit counts as
    ``dram=False`` — the walk never left the package."""
    en = jnp.asarray(enable)
    hit2, w2, s2 = l2_lookup(h.l2, line, bt, geom)
    l2c = l2_touch(h.l2, s2, w2, pressure, tlb_aware, en & hit2)
    go_l3 = en & ~hit2
    l3c, hit3 = l3_access(h.l3, line, go_l3)
    l2c = l2_insert(l2c, line, bt, pressure, tlb_aware, go_l3, geom)
    h, miss_cyc, dram = _dramc_probe(
        h._replace(l3=l3c), line, go_l3 & ~hit3, lat, dramc)
    cycles = jnp.where(
        en,
        jnp.where(hit2, lat.l2, jnp.where(hit3, lat.l3, lat.l3 + miss_cyc)),
        0,
    )
    h = h._replace(
        l2=l2c,
        n_l3_access=h.n_l3_access + go_l3.astype(jnp.int32),
        n_l3_trans=h.n_l3_trans + go_l3.astype(jnp.int32),
    )
    return h, cycles, dram
