"""qwen3-32b [dense] — qk_norm, GQA [hf:Qwen/Qwen3-8B; hf]."""
import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-32b",
    family="dense",
    n_layers=64,
    d_model=5120,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,  # qwen3 uses explicit 128 (not d_model/n_heads)
    d_ff=25600,
    vocab_size=151_936,
    qk_norm=True,
    rope_theta=1_000_000.0,
)

SMOKE = dataclasses.replace(
    CONFIG, name="qwen3-32b-smoke", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, head_dim=16, d_ff=128, vocab_size=512)
