"""mixtral-8x7b [moe] — 8 experts top-2, SWA [arXiv:2401.04088]."""
import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b",
    family="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=32_000,
    window=4096,        # sliding-window attention
    n_experts=8,
    top_k=2,
)

SMOKE = dataclasses.replace(
    CONFIG, name="mixtral-8x7b-smoke", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, d_ff=128, vocab_size=512, window=32, n_experts=4, top_k=2)
