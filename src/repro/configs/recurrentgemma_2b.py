"""recurrentgemma-2b [hybrid] — RG-LRU + local attn, 1:2 [arXiv:2402.19427]."""
import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,       # MQA
    head_dim=256,
    d_ff=7680,
    vocab_size=256_000,
    block_pattern=("rec", "rec", "attn"),
    local_window=2048,
    lru_width=2560,
    tie_embeddings=True,
)

SMOKE = dataclasses.replace(
    CONFIG, name="recurrentgemma-2b-smoke", n_layers=5, d_model=64,
    n_heads=4, n_kv_heads=1, head_dim=16, d_ff=128, vocab_size=512,
    local_window=16, lru_width=64)
