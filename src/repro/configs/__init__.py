"""Config registry: one module per assigned architecture."""
from __future__ import annotations

import importlib

ARCHS = [
    "qwen3-32b",
    "phi3-medium-14b",
    "granite-3-2b",
    "yi-6b",
    "mamba2-2.7b",
    "mixtral-8x7b",
    "granite-moe-1b-a400m",
    "seamless-m4t-medium",
    "recurrentgemma-2b",
    "qwen2-vl-7b",
]


def get_config(name: str):
    mod = importlib.import_module(
        "repro.configs." + name.replace("-", "_").replace(".", "_"))
    return mod.CONFIG


def get_smoke_config(name: str):
    mod = importlib.import_module(
        "repro.configs." + name.replace("-", "_").replace(".", "_"))
    return mod.SMOKE
