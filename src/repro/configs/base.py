"""Model/config system: every assigned architecture is a ModelConfig.

``family`` selects the backbone builder in ``repro.models.model``:
  dense  — decoder-only transformer (GQA, RoPE, SwiGLU, opt. qk_norm/SWA)
  moe    — dense backbone with MoE FFN blocks (top-k routing)
  ssm    — mamba2 (SSD, attention-free)
  hybrid — recurrentgemma (RG-LRU + local attention, repeating pattern)
  encdec — encoder-decoder (seamless-m4t backbone; audio frontend stubbed)
  vlm    — decoder with M-RoPE + vision-patch embedding inputs (stubbed)
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


def _pad256(v: int) -> int:
    return (v + 255) // 256 * 256


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0               # 0 → d_model // n_heads
    # attention options
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    window: Optional[int] = None    # sliding-window attention (mixtral)
    mrope_sections: Optional[Tuple[int, int, int]] = None  # qwen2-vl
    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    # SSM (mamba2)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_groups: int = 1
    ssm_chunk: int = 128
    ssm_conv: int = 4
    # hybrid (recurrentgemma)
    block_pattern: Tuple[str, ...] = ("attn",)  # repeating unit
    local_window: int = 2048
    lru_width: int = 0              # 0 → d_model
    # enc-dec
    n_enc_layers: int = 0
    # frontend stubs
    frontend: Optional[str] = None  # 'audio' | 'vision'
    n_patches: int = 256            # vlm: vision tokens per sequence
    # numerics
    dtype: str = "bfloat16"
    norm_eps: float = 1e-6
    tie_embeddings: bool = False

    @property
    def hd(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.n_heads if self.n_heads else 0

    @property
    def padded_vocab(self) -> int:
        """Vocab padded to a multiple of 256 (TP-friendly; e.g. granite's
        49155 does not divide the 16-way model axis)."""
        return _pad256(self.vocab_size)

    @property
    def d_inner(self) -> int:       # mamba2
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:     # mamba2
        return self.d_inner // self.ssm_headdim

    def n_params(self) -> int:
        """Total parameter count (for 6ND roofline math)."""
        D, F, V, L = self.d_model, self.d_ff, self.padded_vocab, self.n_layers
        hd, H, K = self.hd, self.n_heads, self.n_kv_heads
        emb = V * D * (1 if self.tie_embeddings else 2)
        attn = D * H * hd + 2 * D * K * hd + H * hd * D
        mlp = 3 * D * F
        if self.family == "ssm":
            d_in = self.d_inner
            n = self.ssm_state
            per = (D * (2 * d_in + 2 * self.ssm_groups * n + self.ssm_heads)
                   + d_in * D + self.ssm_conv * (d_in + 2 * self.ssm_groups * n)
                   + 2 * self.ssm_heads)
            return emb + L * (per + 2 * D)
        if self.family == "moe":
            per = attn + self.n_experts * mlp + D * self.n_experts
            return emb + L * (per + 2 * D)
        if self.family == "hybrid":
            W = self.lru_width or D
            rec = D * 2 * W + W * D + 2 * (W * 4) + 3 * W  # gates+proj+conv+lru
            pat = self.block_pattern
            n_rec = sum(1 for b in (pat * ((L // len(pat)) + 1))[:L] if b == "rec")
            n_att = L - n_rec
            return emb + n_rec * (rec + mlp + 2 * D) + n_att * (attn + mlp + 2 * D)
        if self.family == "encdec":
            enc = self.n_enc_layers * (attn + mlp + 2 * D)
            dec = L * (2 * attn + mlp + 3 * D)  # self + cross
            return emb + enc + dec
        return emb + L * (attn + mlp + 2 * D)

    def n_active_params(self) -> int:
        """Activated params per token (MoE: top_k experts only)."""
        if self.family != "moe":
            return self.n_params()
        D, F, L = self.d_model, self.d_ff, self.n_layers
        dense_total = self.n_params()
        unused = L * (self.n_experts - self.top_k) * 3 * D * F
        return dense_total - unused


# ---------------------------------------------------------------- shapes

@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # 'train' | 'prefill' | 'decode'


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}

# archs allowed to run long_500k (sub-quadratic attention path);
# pure full-attention archs skip it per the assignment (see DESIGN.md).
SUBQUADRATIC = {"mamba2-2.7b", "recurrentgemma-2b", "mixtral-8x7b"}


def cell_status(arch: str, shape: str) -> str:
    if shape == "long_500k" and arch not in SUBQUADRATIC:
        return "skipped(full-attention)"
    return "run"
