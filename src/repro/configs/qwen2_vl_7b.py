"""qwen2-vl-7b [vlm] — M-RoPE, dynamic resolution [arXiv:2409.12191].

The vision tower is a STUB: input_specs provides precomputed patch
embeddings; the backbone applies M-RoPE over (t,h,w) position streams.
"""
import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-7b",
    family="vlm",
    n_layers=28,
    d_model=3584,
    n_heads=28,
    n_kv_heads=4,
    d_ff=18944,
    vocab_size=152_064,
    mrope_sections=(16, 24, 24),  # t/h/w split of the 64 rotary dims
    rope_theta=1_000_000.0,
    frontend="vision",
    n_patches=1024,
)

SMOKE = dataclasses.replace(
    CONFIG, name="qwen2-vl-7b-smoke", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, d_ff=128, vocab_size=512, mrope_sections=(4, 6, 6),
    n_patches=8)
