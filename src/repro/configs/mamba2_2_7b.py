"""mamba2-2.7b [ssm] — SSD state-space duality [arXiv:2405.21060]."""
import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b",
    family="ssm",
    n_layers=64,
    d_model=2560,
    n_heads=0,          # attention-free
    n_kv_heads=0,
    d_ff=0,
    vocab_size=50_280,  # padded to 50432
    ssm_state=128,
    ssm_expand=2,
    ssm_headdim=64,     # 80 heads (d_inner 5120 / 64)
    ssm_groups=1,
    ssm_chunk=128,
)

SMOKE = dataclasses.replace(
    CONFIG, name="mamba2-2.7b-smoke", n_layers=2, d_model=64,
    vocab_size=512, ssm_state=16, ssm_headdim=16, ssm_chunk=8)
