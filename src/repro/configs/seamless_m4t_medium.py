"""seamless-m4t-medium [audio] — enc-dec backbone [arXiv:2308.11596].

The speech frontend (fbank → conformer adaptor) is a STUB: input_specs
provides precomputed frame embeddings.
"""
import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    family="encdec",
    n_layers=12,         # decoder layers
    n_enc_layers=12,     # encoder layers
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab_size=256_206,  # padded to 256256
    frontend="audio",
)

SMOKE = dataclasses.replace(
    CONFIG, name="seamless-m4t-medium-smoke", n_layers=2, n_enc_layers=2,
    d_model=64, n_heads=4, n_kv_heads=4, d_ff=128, vocab_size=512)
