"""BENCH_serve.json — serving load harness benchmark matrix.

Replays open-loop arrival traces through the sharded serving engine
(``repro.serve.load``) at two Poisson intensities × two lane counts plus
one bursty-diurnal run, and writes the derived records to
``BENCH_serve.json``.  Every field re-derives bit-exactly from the obs
span trace::

    REPRO_OBS_TRACE=obs_trace_serve.jsonl \
        PYTHONPATH=src python benchmarks/serve_bench.py
    PYTHONPATH=src python -m repro.obs report obs_trace_serve.jsonl \
        --check BENCH_serve.json

``--tune-gate`` closes the sim↔serving loop: the simulator's PTW-CP
collect sweep refits the comparator box and its lower edges become the
engine's cluster-install gate (``load.tune_gate``).

``REPRO_SERVE_TICKS`` (or ``--ticks``) sizes the trace — CI runs a tiny
smoke matrix; production runs stretch to hundreds of thousands of
arrivals by raising ticks/rates.
"""
from __future__ import annotations

import argparse
import json
import os

import jax

from repro.serve import engine, load


def build_matrix(args, cfg):
    """(run_name, arrival, rate, lanes, trace) for the benchmark grid."""
    rates = [float(r) for r in args.rates.split(",")]
    lane_counts = [int(x) for x in args.lanes.split(",")]
    matrix = []
    for lanes in lane_counts:
        for rate in rates:
            total = rate * lanes
            matrix.append((
                f"poisson_r{rate:g}_l{lanes}", "poisson", total, lanes,
                load.poisson_trace(total, args.ticks, cfg, seed=17)))
    # one bursty diurnal run at the top intensity on the widest mesh
    lanes, rate = lane_counts[-1], rates[-1]
    total = rate * lanes
    matrix.append((
        f"diurnal_r{rate:g}_l{lanes}", "diurnal", total, lanes,
        load.diurnal_trace(total, args.ticks, cfg, seed=23)))
    return matrix


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--ticks", type=int,
                    default=int(os.environ.get("REPRO_SERVE_TICKS", 300)))
    ap.add_argument("--rates", default="0.5,2.0",
                    help="per-lane Poisson intensities (req/tick)")
    ap.add_argument("--lanes", default="1,2",
                    help="lane counts (mesh shapes when devices allow)")
    ap.add_argument("--pool-pages", type=int, default=192,
                    help="KV pool size per lane (small enough that the "
                         "bursty run exercises pool backpressure)")
    ap.add_argument("--tune-gate", action="store_true",
                    help="fit the cluster-install gate from the "
                         "simulator's PTW-CP collect sweep")
    ap.add_argument("--tune-n", type=int, default=20_000,
                    help="sim trace length for --tune-gate")
    ap.add_argument("--out", default="BENCH_serve.json")
    args = ap.parse_args(argv)

    gate = (1, 1)
    if args.tune_gate:
        gate = load.tune_gate(n=args.tune_n)
        print(f"PTW-CP refit gate (freq_min, cost_min) = {gate}")
    cfg = engine.EngineConfig(n_pool_pages=args.pool_pages,
                              gate_freq_min=gate[0], gate_cost_min=gate[1])

    for name, arrival, rate, lanes, trace in build_matrix(args, cfg):
        rec = load.run_load(trace, cfg, lanes=lanes, run=name,
                            arrival=arrival, rate=rate)
        print(f"{name:>24}: {rec['n_arrivals']:>5} arrivals  "
              f"p50 {rec['decode_p50_s']}s p99 {rec['decode_p99_s']}s  "
              f"{rec['throughput_rps']} req/s  "
              f"vtc {rec['vtc_hit_rate']:.4f}  "
              f"rejected {rec['rejected']} stall {rec['pool_stall']}")

    art = {"schema": 1, "devices": jax.local_device_count(),
           "gate": list(gate), "serve_runs": load.SERVE_PERF}
    with open(args.out, "w", encoding="utf-8") as f:
        json.dump(art, f, indent=1)
        f.write("\n")
    print(f"wrote {args.out} ({len(load.SERVE_PERF)} runs)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
