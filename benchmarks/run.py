# One function per paper table/figure. Prints ``name,us_per_call,derived``
# CSV rows.  Heavy simulation results come from the disk cache populated by
# ``python -m repro.sim.sweep`` (run benches after the sweep, or each bench
# computes what it is missing).
import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="substring filter on benchmark function names")
    args = ap.parse_args()

    from benchmarks import paper, serving

    fns = list(paper.ALL) + list(serving.ALL)
    if args.only:
        fns = [f for f in fns if args.only in f.__name__]

    print("name,us_per_call,derived")
    failures = 0
    for fn in fns:
        try:
            for name, us, derived in fn():
                print(f"{name},{us:.3f},{derived}", flush=True)
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"{fn.__name__},nan,ERROR {e!r}", flush=True)
            traceback.print_exc(file=sys.stderr)
    # sweep-throughput trajectory (per-ladder compile+sim wall times,
    # systems-per-compile) — CI uploads it to track regressions
    print(f"# wrote {paper.write_sweep_artifact()}", file=sys.stderr)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
