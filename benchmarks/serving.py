"""Framework-side benchmarks: the Victima Translation Cache in the paged-KV
serving stack (the TPU adaptation), plus model-throughput microbenches."""
from __future__ import annotations

import time

import jax


def vtc_serving_hit_rates():
    """Walk-rate with/without the Victima cluster tier during a decode
    storm (serving analogue of Fig. 21 PTW reduction)."""
    import repro.obs as obs
    from repro.serve import engine
    cfg = engine.EngineConfig(n_slots=8, max_blocks_per_req=32,
                              n_pool_pages=512, n_leaf_rows=64,
                              tc_sets=16, tc_ways=2, n_clusters=64)
    st = engine.init(cfg)
    for slot in range(8):
        st, _ok = engine.admit(st, slot, 2 + slot % 3)
    t0 = time.time()
    ticks = 700  # cross several 128-token block boundaries per slot
    step = jax.jit(lambda s: engine.decode_translate(s, cfg))
    for _ in range(ticks):
        # the instrumented entry point: per-tick latency lands in the
        # obs registry's serve.decode_step_s[vtc] histogram (scoped per
        # engine so the ablation below cannot contaminate it)
        st, phys, src = engine.decode_step(st, cfg, fn=step, scope="vtc")
    us = (time.time() - t0) * 1e6 / (ticks * cfg.n_slots)
    stats_vtc = engine.stats(st, scope="vtc")
    lat = obs.REGISTRY.hist_stats(
        engine.scoped(obs.names.HIST_DECODE_STEP_S, "vtc"))
    # no-cluster ablation — its own registry scope: the two engines'
    # inc_to counters must never merge into a max-of-both
    cfg2 = engine.EngineConfig(n_slots=8, max_blocks_per_req=32,
                               n_pool_pages=512, n_leaf_rows=64,
                               tc_sets=16, tc_ways=2, n_clusters=1)
    st2 = engine.init(cfg2)
    for slot in range(8):
        st2, _ok = engine.admit(st2, slot, 2 + slot % 3)
    step2 = jax.jit(lambda s_: engine.decode_translate(s_, cfg2))
    for _ in range(700):
        st2, _, _ = step2(st2)
    stats_novtc = engine.stats(st2, scope="novtc")
    return [
        ("serve_vtc_walk_rate", us,
         f"{stats_vtc['walk_rate']*100:.0f}% with clusters vs "
         f"{stats_novtc['walk_rate']*100:.0f}% without (Victima layer)"),
        ("serve_vtc_tc_hit", us, f"{stats_vtc['tc_hit_rate']*100:.0f}%"),
        ("serve_vtc_cluster_hit", us,
         f"{stats_vtc['cluster_hit_rate']*100:.0f}%"),
        ("serve_vtc_hit_rate", us,
         f"{stats_vtc['vtc_hit_rate']*100:.0f}% walk-free translations"),
        ("serve_decode_p99_us", lat["p99"] * 1e6,
         f"p50 {lat['p50']*1e6:.0f}us over {lat['count']} ticks"),
    ]


def model_step_times():
    """Per-token CPU step time for three smoke models (sanity scale)."""
    from repro.configs import get_smoke_config
    from repro.models.model import build, dummy_batch
    rows = []
    for arch in ["granite-3-2b", "mamba2-2.7b", "mixtral-8x7b"]:
        cfg = get_smoke_config(arch)
        m = build(cfg)
        params = m.init(jax.random.PRNGKey(0))
        batch = dummy_batch(cfg, 2, 64)
        fwd = jax.jit(lambda p, b: m.forward(p, b, remat=False))
        fwd(params, batch).block_until_ready()
        t0 = time.time()
        for _ in range(5):
            fwd(params, batch).block_until_ready()
        us = (time.time() - t0) * 1e6 / (5 * 2 * 64)
        rows.append((f"model_fwd_us_per_tok_{arch}", us, "smoke-scale CPU"))
    return rows


ALL = [vtc_serving_hit_rates, model_step_times]
