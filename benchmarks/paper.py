"""One benchmark per paper table/figure (§9 + §3 motivation).

Every function returns a list of CSV rows (name, us_per_call, derived)
where us_per_call is the simulation cost per traced access and `derived`
carries the headline metric with the paper's value for comparison.
Results come from the disk-cached sweep (repro.sim.sweep); anything
missing is computed on demand.
"""
from __future__ import annotations

import json
import os
import time

import jax
import numpy as np

from repro.core import metrics, timing
from repro.sim import runner, systems, trace_gen
from repro.sim.runner import run_batch, run_ladder

# REPRO_SIM_WLS=bc,xs,rnd restricts the workload set (CI runs a small
# deterministic subset to keep the sweep-perf artifact cheap)
_WLS_ENV = os.environ.get("REPRO_SIM_WLS", "")
WLS = ([w for w in _WLS_ENV.split(",") if w] if _WLS_ENV
       else trace_gen.all_workloads())
_BAD_WLS = sorted(set(WLS) - set(trace_gen.WORKLOADS))
if _BAD_WLS:
    # fail up front with the knob named — a typo used to surface as a
    # bare KeyError from a trace-generation worker thread mid-sweep
    raise SystemExit(
        f"REPRO_SIM_WLS: unknown workload(s) {', '.join(_BAD_WLS)}; "
        f"known: {', '.join(trace_gen.WORKLOADS)}")
N = int(os.environ.get("REPRO_SIM_N", 150_000))

# systems covered by a batched (vmapped) ladder run: the first _sys()
# touching a ladder member fills the whole ladder in one compilation.
# Ladders are auto-discovered from the registry (systems.LADDERS), so
# every member of e.g. the 18-system radix/victima family — including
# the whole Fig. 25 L2-cache-size family — takes the batched path.
_LADDER_OF = {s: lad for lad, members in systems.LADDERS.items()
              for s in members}


def _sys(name):
    if name in _LADDER_OF:
        # fill the whole ladder's cache in one batched compile; the timed
        # call below then measures this system's retrieval like any other
        # warm-cache system
        run_ladder(_LADDER_OF[name], workloads=WLS, n=N)
    t0 = time.time()
    out = run_batch(name, workloads=WLS, n=N)
    us = (time.time() - t0) * 1e6 / (N * len(WLS))
    return out, us


def _gmean_speedup(base, new):
    sp = []
    for w in WLS:
        b, _, spec = base[w]
        n, _, _ = new[w]
        sp.append(timing.speedup(b, n, spec.ipa))
    return float(np.exp(np.mean(np.log(sp))))


def _avg(fn, out):
    return float(np.mean([fn(out[w][0], out[w][2]) for w in WLS]))


# ---------------------------------------------------------------- §3


def fig4_ptw_latency():
    out, us = _sys("radix")
    walks = _avg(lambda s, sp: metrics.avg_walk_cycles(s), out)
    return [("fig4_avg_ptw_latency_cycles", us,
             f"{walks:.0f} (paper 137)")]


def fig5_fig6_fig7_l2tlb_scaling():
    rows = []
    base, us = _sys("radix")
    mpki0 = _avg(lambda s, sp: metrics.l2tlb_mpki(s, sp.ipa), base)
    rows.append(("fig5_mpki_1.5K", us, f"{mpki0:.1f} (paper 39)"))
    for tag, label in [("l2tlb_3k", "3K"), ("l2tlb_8k", "8K"),
                       ("l2tlb_16k", "16K"), ("l2tlb_32k", "32K"),
                       ("l2tlb_64k", "64K"), ("l2tlb_128k", "128K")]:
        out, us = _sys(tag)
        mpki = _avg(lambda s, sp: metrics.l2tlb_mpki(s, sp.ipa), out)
        sp = _gmean_speedup(base, out)
        rows.append((f"fig5_mpki_{label}", us, f"{mpki:.1f}"))
        rows.append((f"fig6_speedup_opt_{label}", us,
                     f"{(sp-1)*100:.1f}% (paper 64K: +4.0%)"))
    for tag, label in [("l2tlb_8k_real", "8K@17c"),
                       ("l2tlb_16k_real", "16K@23c"),
                       ("l2tlb_32k_real", "32K@30c"),
                       ("l2tlb_64k_real", "64K@39c")]:
        out, us = _sys(tag)
        sp = _gmean_speedup(base, out)
        rows.append((f"fig7_speedup_real_{label}", us,
                     f"{(sp-1)*100:.1f}% (paper 64K: +0.8%)"))
    return rows


def fig8_l3tlb():
    base, _ = _sys("radix")
    rows = []
    for tag, label in [("l3tlb_64k_15", "15c"), ("l3tlb_64k_24", "24c"),
                       ("l3tlb_64k_39", "39c")]:
        out, us = _sys(tag)
        sp = _gmean_speedup(base, out)
        rows.append((f"fig8_l3tlb_{label}", us,
                     f"{(sp-1)*100:.1f}% (paper 15c: +2.9%)"))
    return rows


def fig9_stlb_miss_latency():
    rows = []
    for tag, paperv in [("radix", 128), ("pom", 122), ("np", 275),
                        ("pom_virt", 220)]:
        out, us = _sys(tag)
        lat = _avg(lambda s, sp: metrics.avg_l2tlb_miss_latency(s), out)
        rows.append((f"fig9_l2miss_lat_{tag}", us,
                     f"{lat:.0f} cyc (paper {paperv})"))
    return rows


def fig11_reuse():
    out, us = _sys("radix")
    zr = float(np.mean([metrics.zero_reuse_fraction(
        out[w][1]["hist_reuse_data"]) for w in WLS]))
    return [("fig11_zero_reuse_frac", us, f"{zr*100:.0f}% (paper 92%)")]


# ---------------------------------------------------------------- Table 2


def table2_ptwcp():
    from repro.core import ptwcp_nn
    out, us = _sys("radix_collect")
    extras = [out[w][1] for w in WLS]
    rows = []
    for r in ptwcp_nn.run_study(extras):
        rows.append((f"table2_{r.name}", us,
                     f"acc {r.accuracy*100:.1f}% prec {r.precision*100:.1f}%"
                     f" rec {r.recall*100:.1f}% F1 {r.f1*100:.1f}%"
                     f" ({r.params_bytes}B)"
                     + (" (paper: F1 80.7%, 24B)"
                        if r.name == "Comparator" else "")))
    return rows


# ---------------------------------------------------------------- §9 native


def fig20_native_speedup():
    base, _ = _sys("radix")
    rows = []
    for tag, paperv in [("pom", "+1.2"), ("l3tlb_64k_15", "+2.9"),
                        ("l2tlb_64k", "+4.0"), ("l2tlb_128k", "+7.1"),
                        ("victima", "+7.4")]:
        out, us = _sys(tag)
        sp = _gmean_speedup(base, out)
        rows.append((f"fig20_speedup_{tag}", us,
                     f"{(sp-1)*100:.1f}% (paper {paperv}%)"))
    return rows


def fig21_ptw_reduction():
    base, _ = _sys("radix")
    rows = []
    for tag, paperv in [("pom", 37), ("l2tlb_64k", 37),
                        ("l2tlb_128k", 48), ("victima", 50)]:
        out, us = _sys(tag)
        red = float(np.mean([metrics.ptw_reduction(base[w][0], out[w][0])
                             for w in WLS]))
        rows.append((f"fig21_ptw_red_{tag}", us,
                     f"{red*100:.0f}% (paper {paperv}%)"))
    return rows


def fig22_miss_latency():
    base, _ = _sys("radix")
    rows = []
    for tag, paperv in [("pom", 3), ("victima", 22)]:
        out, us = _sys(tag)
        b = _avg(lambda s, sp: metrics.avg_l2tlb_miss_latency(s), base)
        n = _avg(lambda s, sp: metrics.avg_l2tlb_miss_latency(s), out)
        rows.append((f"fig22_l2miss_lat_red_{tag}", us,
                     f"{(1-n/b)*100:.0f}% (paper {paperv}%)"))
    return rows


def fig23_reach():
    out, us = _sys("victima")
    reach = _avg(lambda s, sp: metrics.translation_reach_mb(s), out)
    base_reach = metrics.baseline_l2tlb_reach_mb()
    return [("fig23_translation_reach", us,
             f"{reach:.0f} MB = {reach/base_reach:.0f}x L2TLB "
             f"(paper 220MB/36x)")]


def fig24_tlb_block_reuse():
    out, us = _sys("victima")
    hr = float(np.mean([metrics.high_reuse_fraction(
        out[w][1]["hist_reuse_tlb"]) for w in WLS]))
    return [("fig24_tlb_block_reuse_gt20", us,
             f"{hr*100:.0f}% (paper 65%)")]


def fig25_cache_size():
    rows = []
    for size, vtag, rtag in [("1MB", "victima_l2_1m", "radix_l2_1m"),
                             ("2MB", "victima", "radix"),
                             ("4MB", "victima_l2_4m", "radix_l2_4m"),
                             ("8MB", "victima_l2_8m", "radix_l2_8m")]:
        v, us = _sys(vtag)
        r, _ = _sys(rtag)
        red = float(np.mean([metrics.ptw_reduction(r[w][0], v[w][0])
                             for w in WLS]))
        rows.append((f"fig25_ptw_red_{size}", us,
                     f"{red*100:.0f}% (paper 8MB: 63%)"))
    return rows


def fig26_policy():
    ag, us = _sys("victima_agnostic")
    aw, _ = _sys("victima")
    sp = _gmean_speedup(ag, aw)
    return [("fig26_tlb_aware_vs_agnostic", us,
             f"+{(sp-1)*100:.1f}% (paper +1.8%)")]


def ablation_ptwcp():
    """Beyond-paper: Victima with insert-always (no PTW-CP)."""
    nop, us = _sys("victima_noptwcp")
    yes, _ = _sys("victima")
    sp = _gmean_speedup(nop, yes)
    return [("ablation_ptwcp_gain", us, f"+{(sp-1)*100:.1f}% vs no-PTWCP")]


def utopia_comparison():
    """Beyond-paper: Utopia (PAPERS.md) vs Victima, from ONE compiled
    ladder call — radix / utopia / victima / victima+utopia are all
    members of the discovered native family, so the first `_sys` fills
    every row's cache in a single vmapped compile.  The paper positions
    Victima +6.2% over a state-of-the-art SW-TLB; this table puts the
    hybrid-mapping alternative on the same axis."""
    base, _ = _sys("radix")
    rows = []
    for tag in ("utopia", "victima", "utopia_victima"):
        out, us = _sys(tag)
        sp = _gmean_speedup(base, out)
        red = float(np.mean([metrics.ptw_reduction(base[w][0], out[w][0])
                             for w in WLS]))
        rows.append((f"utopia_cmp_speedup_{tag}", us,
                     f"+{(sp-1)*100:.1f}% vs radix, "
                     f"{red*100:.0f}% fewer PTWs"))
    out, us = _sys("utopia")
    hr = _avg(lambda s, sp: metrics.restseg_hit_rate(s), out)
    cr = _avg(lambda s, sp: metrics.restseg_conflict_rate(s), out)
    pc = _avg(lambda s, sp: metrics.avg_restseg_probe_cycles(s), out)
    rows.append(("utopia_restseg_hit_rate", us,
                 f"{hr*100:.0f}% of probes walk-free "
                 f"({cr*100:.0f}% migrations conflict, "
                 f"{pc:.0f} cyc/probe)"))
    for tag in ("utopia_rs8", "utopia_rs32"):
        out, us = _sys(tag)
        sp = _gmean_speedup(base, out)
        rows.append((f"utopia_sens_{tag}", us,
                     f"+{(sp-1)*100:.1f}% vs radix"))
    return rows


def _walks_issued(stats) -> float:
    """Walks the system actually executed: demand walks PLUS Revelator's
    overlapped verification walks (every speculative resolution runs
    one; they are excluded from n_demand_ptw by design)."""
    return (float(stats.n_demand_ptw) + float(stats.n_rev_hit)
            + float(stats.n_rev_mispred))


def scheme_comparison():
    """Beyond-paper: the full translation-scheme matrix — radix /
    Victima (reach) / Utopia (mapping) / Revelator (speculation) — on
    shared hardware assumptions, all members of the ONE discovered
    native ladder, so the whole table fills from a single compiled
    vmapped call.  Victima/Utopia *eliminate* walks; Revelator *hides*
    them (verification walks still execute, overlapped).  The table
    reports both axes: critical-path PTW reduction (n_demand_ptw) and
    walks-issued reduction (demand + verification) — for Revelator the
    first is large and the second ~0, which IS the scheme's point."""
    base, _ = _sys("radix")
    rows = []
    for tag in ("victima", "utopia", "revelator",
                "utopia_victima", "revelator_victima"):
        out, us = _sys(tag)
        sp = _gmean_speedup(base, out)
        red = float(np.mean([metrics.ptw_reduction(base[w][0], out[w][0])
                             for w in WLS]))
        issued = float(np.mean([
            metrics.reduction(_walks_issued(base[w][0]),
                              _walks_issued(out[w][0])) for w in WLS]))
        rows.append((f"scheme_cmp_{tag}", us,
                     f"{(sp-1)*100:+.1f}% vs radix, "
                     f"{red*100:.0f}% fewer critical-path PTWs, "
                     f"{issued*100:.0f}% fewer walks issued"))
        if tag == "revelator":
            cov = _avg(lambda s, sp: metrics.rev_coverage(s), out)
            acc = _avg(lambda s, sp: metrics.rev_accuracy(s), out)
            vc = _avg(lambda s, sp: metrics.avg_rev_verify_cycles(s), out)
            rows.append(("scheme_cmp_rev_speculation", us,
                         f"{cov*100:.0f}% of L2-TLB misses speculated "
                         f"({acc*100:.0f}% verified correct, "
                         f"{vc:.0f} cyc/verify overlapped)"))
    return rows


# ------------------------------------------------------------- multicore


MC_MIX = os.environ.get("REPRO_SIM_MIX", "bc+rnd+xs")


def _mc_sys(name, workload):
    """Warm one multicore system through its batched family ladder, then
    return its (possibly per-core-tuple) result for `workload`."""
    if name in _LADDER_OF:
        run_ladder(_LADDER_OF[name], workloads=[workload], n=N)
    t0 = time.time()
    out = run_batch(name, workloads=[workload], n=N)
    us = (time.time() - t0) * 1e6 / N
    return out[workload], us


def _lanes(result):
    """Normalize a sim result to per-core-lane tuples: multicore results
    are already (stats..., extras..., specs...); single-core results
    become 1-lane tuples so the same reductions apply."""
    stats, extras, _ = result
    # Stats is itself a NamedTuple, so detect the per-core tuple by the
    # ABSENCE of NamedTuple fields on the outer value
    if isinstance(stats, tuple) and not hasattr(stats, "_fields"):
        return stats, extras
    return (stats,), (extras,)


def multicore_scaling():
    """Beyond-paper: multicore MMU scaling.  Each core count's whole
    {radix, victima, pom, victima+DRAM-cache} family fills from ONE
    compiled vmapped ladder call — per-core private TLB hierarchies
    share a capacity-partitioned, port-contended L3/POM tier, with the
    multiprogrammed mix round-robined across the core lanes (1 core
    degenerates to the mix's first component).  Rows report the mean
    per-core critical-path PTW reduction vs the same-C radix baseline
    and how much of the shared L3's traffic is translation metadata
    (TLB blocks + PTE lines) — the paper's underutilized-cache argument
    under multiprogrammed contention."""
    names = trace_gen.parse_mix(MC_MIX)
    rows = []
    for c in (1, 2, 4):
        wl = MC_MIX if c > 1 else names[0]
        base, _ = _mc_sys(f"radix_{c}c", wl)
        b_stats, _ = _lanes(base)
        for scheme in ("victima", "pom", "victima_dramc"):
            out, us = _mc_sys(f"{scheme}_{c}c", wl)
            s_stats, s_extras = _lanes(out)
            red = metrics.mean_ptw_reduction(b_stats, s_stats)
            share = float(np.mean(
                [metrics.l3_translation_share(e) for e in s_extras]))
            derived = (f"{red*100:.0f}% fewer per-core PTWs, "
                       f"L3 {share*100:.1f}% translation traffic")
            if scheme == "victima_dramc":
                hit = float(np.mean(
                    [metrics.dramc_hit_rate(e) for e in s_extras]))
                derived += f", dramc hit {hit*100:.0f}%"
            rows.append((f"multicore_{c}c_{scheme}", us, derived))
    return rows


# ---------------------------------------------------------------- §9 virt


def fig27_virt_speedup():
    base, _ = _sys("np")
    rows = []
    for tag, paperv in [("pom_virt", "+7.2"), ("isp", "+22.7"),
                        ("victima_virt", "+28.7")]:
        out, us = _sys(tag)
        sp = _gmean_speedup(base, out)
        rows.append((f"fig27_virt_speedup_{tag}", us,
                     f"{(sp-1)*100:.1f}% (paper {paperv}%)"))
    return rows


def fig28_guest_host_ptws():
    base, _ = _sys("np")
    out, us = _sys("victima_virt")
    g = float(np.mean([metrics.ptw_reduction(base[w][0], out[w][0])
                       for w in WLS]))
    h = float(np.mean([
        metrics.host_ptw_reduction(base[w][0], out[w][0])
        for w in WLS]))
    return [("fig28_guest_ptw_red", us, f"{g*100:.0f}% (paper 50%)"),
            ("fig28_host_ptw_red", us, f"{h*100:.0f}% (paper 99%)")]


def fig29_virt_miss_latency():
    base, _ = _sys("np")
    rows = []
    for tag, paperv in [("pom_virt", 20), ("isp", 54),
                        ("victima_virt", 60)]:
        out, us = _sys(tag)
        b = _avg(lambda s, sp: metrics.avg_l2tlb_miss_latency(s), base)
        n = _avg(lambda s, sp: metrics.avg_l2tlb_miss_latency(s), out)
        rows.append((f"fig29_virt_l2miss_red_{tag}", us,
                     f"{(1-n/b)*100:.0f}% (paper ~{paperv}%)"))
    return rows


def backend_speedup_line(fills=None) -> str | None:
    """One printable scan-vs-pallas line from this process's fills.

    Compares ``compile_plus_sim_wall_s`` between the largest same-shape
    (ladder, sim_n) fill pair that ran under both backends; returns
    None when only one backend ran (the common case outside the
    benchmark job, where nothing should print).
    """
    fills = runner.LADDER_PERF if fills is None else fills
    best = {}
    for f in fills:
        key = (f["ladder"], f["sim_n"], f["n_workloads"])
        best.setdefault(key, {})[f.get("backend", "scan")] = f
    pairs = [(k, v) for k, v in best.items()
             if "scan" in v and "pallas" in v]
    if not pairs:
        return None
    key, v = max(pairs, key=lambda kv: kv[0][1] * kv[0][2])
    scan_s = v["scan"]["compile_plus_sim_wall_s"]
    pal_s = v["pallas"]["compile_plus_sim_wall_s"]
    if not pal_s:
        return None
    return (f"[sweep-perf] {key[0]} n={key[1]}: scan {scan_s:.1f}s vs "
            f"pallas {pal_s:.1f}s (block {v['pallas'].get('block')}) -> "
            f"{scan_s / pal_s:.2f}x")


def write_sweep_artifact(path: str | None = None) -> str:
    """Dump the sweep-throughput trajectory to BENCH_sweep.json.

    Records every batched ladder fill this process ran plus the
    registry's current ladder shapes, so CI can diff sweep throughput
    across PRs — a registry entry silently falling out of its batched
    family shows up here as a shrunk systems-per-compile long before it
    costs minutes.  Schema 3: each ``ladder_fills`` record splits the
    pipeline stages (``trace_gen_wall_s`` = generation not hidden
    behind simulation, ``compile_plus_sim_wall_s`` = the compiled
    shard_map dispatches) and carries ``devices``/``mesh``/``chunk``
    metadata plus — new in 3 — the access-loop ``backend``, pallas
    ``block`` size, ``t_shards``/``t_rounds`` hand-off counts and
    whether the chunk width was auto-tuned (``chunk_auto``); the host
    device count rides at top level too.  New in 4: each fill carries
    its one-compile accounting — ``n_members`` (family width vmapped
    through the single dispatch graph), ``dispatch_compiles`` (actual
    compile count of that graph, measured via ``jax_log_compiles``)
    and ``one_compile`` (whether the invariant held; the time-shard
    path re-jits per chunk and records False honestly).  New in 5:
    fills are no longer hand-assembled — ``runner.run_ladder`` derives
    them from its obs span trace (``obs.report.fill_record``), and two
    fields ride along: ``trace_gen_true_wall_s`` (producer-side thread
    time, vs the consumer-side wait ``trace_gen_wall_s``) and
    ``trace_file`` (the JSONL the record derives from — ``python -m
    repro.obs report <trace> --check <artifact>`` re-derives every
    record bit-exactly; schema-4 fields are unchanged).  New in 6:
    each fill carries ``cores`` — the per-system core-lane count (1
    for every single-core family; C for the multicore families whose
    multiprogrammed mixes ride the core axis) — and schema-5 fields
    are bit-compatible.  When fills ran under both backends, a
    scan-vs-pallas speedup line is printed so the perf trajectory is
    visible per PR.
    """
    path = path or os.environ.get("REPRO_BENCH_SWEEP", "BENCH_sweep.json")
    artifact = {
        "schema": 6,
        "sim_n": N,
        "devices": jax.local_device_count(),
        "workloads": WLS,
        "ladders": {lad: {"n_systems": len(members), "members": members}
                    for lad, members in systems.LADDERS.items()},
        "ladder_fills": runner.LADDER_PERF,
    }
    line = backend_speedup_line()
    if line:
        print(line, flush=True)
    with open(path, "w") as f:
        json.dump(artifact, f, indent=2, sort_keys=True)
        f.write("\n")
    return path


ALL = [
    fig4_ptw_latency,
    fig5_fig6_fig7_l2tlb_scaling,
    fig8_l3tlb,
    fig9_stlb_miss_latency,
    fig11_reuse,
    table2_ptwcp,
    fig20_native_speedup,
    fig21_ptw_reduction,
    fig22_miss_latency,
    fig23_reach,
    fig24_tlb_block_reuse,
    fig25_cache_size,
    fig26_policy,
    ablation_ptwcp,
    utopia_comparison,
    scheme_comparison,
    multicore_scaling,
    fig27_virt_speedup,
    fig28_guest_host_ptws,
    fig29_virt_miss_latency,
]
