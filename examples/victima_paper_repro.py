"""Reproduce the paper's headline results end to end (native + virt).

Runs the full evaluated-system matrix on all 11 workloads (cached sweep
results are reused when present) and prints a side-by-side against the
paper's reported numbers.

    PYTHONPATH=src python examples/victima_paper_repro.py
"""
import numpy as np

from repro.core import metrics, timing
from repro.sim import trace_gen
from repro.sim.runner import run_batch

WLS = trace_gen.all_workloads()


def gmean_speedup(base, new):
    sp = [timing.speedup(base[w][0], new[w][0], base[w][2].ipa) for w in WLS]
    return float(np.exp(np.mean(np.log(sp))))


def main():
    print("== native execution ==")
    radix = run_batch("radix")
    vic = run_batch("victima")
    pom = run_batch("pom")
    l2128 = run_batch("l2tlb_128k")
    rows = [
        ("Victima vs Radix", gmean_speedup(radix, vic), "+7.4%"),
        ("Victima vs POM-TLB",
         gmean_speedup(pom, vic), "+6.2%"),
        ("Victima vs Opt.L2TLB-128K",
         gmean_speedup(l2128, vic), "≈ +0.3%"),
    ]
    for name, sp, paper in rows:
        print(f"  {name:28s} {(sp-1)*100:+6.1f}%   (paper {paper})")
    red = np.mean([metrics.ptw_reduction(radix[w][0], vic[w][0])
                   for w in WLS])
    print(f"  {'PTW reduction':28s} {red*100:6.1f}%   (paper 50%)")
    reach = np.mean([metrics.translation_reach_mb(vic[w][0]) for w in WLS])
    print(f"  {'translation reach':28s} {reach:6.0f}MB   (paper 220MB)")

    print("== virtualized execution (nested paging) ==")
    npg = run_batch("np")
    vvirt = run_batch("victima_virt")
    isp = run_batch("isp")
    pomv = run_batch("pom_virt")
    rows = [
        ("Victima vs NP", gmean_speedup(npg, vvirt), "+28.7%"),
        ("Victima vs POM-TLB", gmean_speedup(pomv, vvirt), "+20.1%"),
        ("Victima vs Ideal-SP", gmean_speedup(isp, vvirt), "+4.9%"),
    ]
    for name, sp, paper in rows:
        print(f"  {name:28s} {(sp-1)*100:+6.1f}%   (paper {paper})")
    h = np.mean([1 - float(vvirt[w][0].n_host_ptw)
                 / max(float(npg[w][0].n_host_ptw), 1) for w in WLS])
    g = np.mean([metrics.ptw_reduction(npg[w][0], vvirt[w][0]) for w in WLS])
    print(f"  {'guest PTW reduction':28s} {g*100:6.1f}%   (paper 50%)")
    print(f"  {'host PTW reduction':28s} {h*100:6.1f}%   (paper 99%)")


if __name__ == "__main__":
    main()
