"""Batched serving with the Victima Translation Cache.

Drives the paged-KV engine through a request storm: admissions, lock-step
decode (translations through TC → cluster pages → radix walk), retirement
shootdowns — and prints the translation-path mix, demonstrating the
paper's mechanism inside the serving stack (DESIGN.md §2.2).

    PYTHONPATH=src python examples/serve_paged.py --ticks 200
"""
import argparse

import jax
import numpy as np

from repro.serve import engine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--ticks", type=int, default=200)
    ap.add_argument("--slots", type=int, default=8)
    args = ap.parse_args()

    cfg = engine.EngineConfig(n_slots=args.slots, max_blocks_per_req=32,
                              n_pool_pages=1024, n_leaf_rows=128,
                              tc_sets=8, tc_ways=2, n_clusters=128)
    st = engine.init(cfg)
    rng = np.random.default_rng(0)
    for s in range(args.slots):
        st, _ok = engine.admit(st, s, int(rng.integers(1, 6)))
    step = jax.jit(lambda s: engine.decode_translate(s, cfg))

    lifetimes = rng.integers(40, 160, size=args.slots)
    ages = np.zeros(args.slots, int)
    n_served = args.slots
    for t in range(args.ticks):
        st, phys, src = step(st)
        ages += 1
        for s in range(args.slots):
            if ages[s] >= lifetimes[s]:
                # retire + admit a fresh request (continuous batching)
                st = engine.retire(st, s)
                st, _ok = engine.admit(st, s, int(rng.integers(1, 6)))
                ages[s] = 0
                lifetimes[s] = int(rng.integers(40, 160))
                n_served += 1
        if (t + 1) % 50 == 0:
            m = engine.stats(st)
            print(f"tick {t+1:4d}  served={n_served:3d}  "
                  f"TC {m['tc_hit_rate']*100:5.1f}%  "
                  f"cluster {m['cluster_hit_rate']*100:5.1f}%  "
                  f"walk {m['walk_rate']*100:5.1f}%  "
                  f"free pages {m['pages_free']}")

    m = engine.stats(st)
    print("\nfinal translation-path mix (Victima layer active):")
    print(f"  TC hits        {m['tc_hit_rate']*100:5.1f}%   (≈ L2 TLB)")
    print(f"  cluster hits   {m['cluster_hit_rate']*100:5.1f}%   "
          f"(TLB blocks in the KV pool — the paper's mechanism)")
    print(f"  radix walks    {m['walk_rate']*100:5.1f}%   (≈ PTWs)")


if __name__ == "__main__":
    main()
