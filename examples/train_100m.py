"""End-to-end training driver: ~100M-param LM for a few hundred steps.

Uses the full substrate: sharded data pipeline, AdamW + cosine schedule,
remat'd scan-over-layers model, fault-tolerant trainer with async
step-atomic checkpoints — on the local host mesh.

    PYTHONPATH=src python examples/train_100m.py --steps 300
"""
import argparse
import dataclasses

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.data.pipeline import DataConfig, Pipeline
from repro.models.model import build
from repro.optim import adamw
from repro.train.train_step import TrainConfig, init_state, make_train_step
from repro.train.trainer import LoopConfig, Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_100m")
    args = ap.parse_args()

    # ~100M params: granite-3-2b geometry scaled down
    cfg = dataclasses.replace(
        get_config("granite-3-2b"),
        name="granite-100m", n_layers=8, d_model=768, n_heads=12,
        n_kv_heads=4, head_dim=64, d_ff=2048, vocab_size=32_000)
    print(f"model: {cfg.name}  params ≈ {cfg.n_params()/1e6:.0f}M")

    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    state = init_state(params)

    tcfg = TrainConfig(opt=adamw.AdamWConfig(
        lr=6e-4, warmup_steps=20, total_steps=args.steps))
    step_fn = jax.jit(make_train_step(model, tcfg), donate_argnums=(0,))

    data = Pipeline(DataConfig(vocab_size=cfg.vocab_size, batch=args.batch,
                               seq_len=args.seq, seed=0))

    def batch_fn(step):
        return {"tokens": jnp.asarray(data.batch_at(step))}

    trainer = Trainer(step_fn, batch_fn,
                      LoopConfig(total_steps=args.steps, ckpt_every=50,
                                 ckpt_dir=args.ckpt_dir, log_every=10))
    state, start = trainer.resume_or_init(state)
    if start:
        print(f"resumed from checkpoint at step {start}")
    state, hist = trainer.run(state, start)
    print(f"done. loss {hist[0]:.3f} -> {hist[-1]:.3f} over "
          f"{len(hist)} steps; stragglers={trainer.n_stragglers} "
          f"restarts={trainer.n_restarts}")


if __name__ == "__main__":
    main()
