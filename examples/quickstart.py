"""Quickstart: the Victima mechanism in 60 seconds.

Runs the trace-driven simulator on one workload under the baseline Radix
system and under Victima, and prints the paper's headline metrics.

    PYTHONPATH=src python examples/quickstart.py [--workload rnd] [-n 40000]
"""
import argparse

from repro.core import metrics, timing
from repro.sim.runner import run


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--workload", default="rnd")
    ap.add_argument("-n", type=int, default=40_000)
    args = ap.parse_args()

    print(f"simulating '{args.workload}' ({args.n} accesses)…")
    base, bex, spec = run("radix", args.workload, n=args.n)
    vic, vex, _ = run("victima", args.workload, n=args.n)

    print(f"\n=== {args.workload} (ipa={spec.ipa}) ===")
    print(f"L2 TLB MPKI            {metrics.l2tlb_mpki(base, spec.ipa):8.1f}")
    print(f"avg PTW latency        {metrics.avg_walk_cycles(base):8.0f} cyc")
    print(f"translation cycles     "
          f"{timing.translation_fraction(base, spec.ipa)*100:7.1f} %")
    print("--- Victima ---")
    print(f"PTW reduction          "
          f"{metrics.ptw_reduction(base, vic)*100:7.1f} %  (paper avg 50%)")
    print(f"L2-cache TLB-block hits{int(vic.n_victima_hit):8d}")
    print(f"L2TLB miss lat         "
          f"{metrics.avg_l2tlb_miss_latency(base):5.0f} -> "
          f"{metrics.avg_l2tlb_miss_latency(vic):5.0f} cyc")
    print(f"translation reach      "
          f"{metrics.translation_reach_mb(vic):8.0f} MB (paper 220 MB)")
    print(f"end-to-end speedup     "
          f"{(timing.speedup(base, vic, spec.ipa)-1)*100:7.1f} %")


if __name__ == "__main__":
    main()
