"""Distribution-layer lowering tests under a forced multi-device CPU.

Run in subprocesses because XLA device count locks at first jax init.
Covers: compressed-DP train step (EF-int8 over 'pod'), GPipe pipeline
loss over 'pod', and a miniature dryrun cell on a (2,2,2) mesh.
"""
import os
import subprocess
import sys
import textwrap

import pytest

# the distribution layer is not part of this tree yet; these lowering
# tests resume automatically once a PR adds repro.dist
pytest.importorskip("repro.dist", reason="repro.dist not in tree")

ENV = dict(os.environ,
           XLA_FLAGS="--xla_force_host_platform_device_count=8",
           PYTHONPATH="src",
           JAX_PLATFORMS="cpu")


def _run(code: str):
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, env=ENV,
                       cwd="/root/repo", timeout=900)
    assert r.returncode == 0, r.stdout + r.stderr
    return r.stdout


@pytest.mark.slow
def test_compressed_pod_train_step_lowers():
    out = _run("""
        import jax, jax.numpy as jnp
        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        from repro.configs import get_smoke_config
        from repro.models.model import build, dummy_batch
        from repro.train.train_step import TrainConfig, init_state
        from repro.dist.compress import (init_error_state,
                                         make_compressed_train_step)
        cfg = get_smoke_config("granite-3-2b")
        m = build(cfg)
        params = m.init(jax.random.PRNGKey(0))
        state = init_state(params)
        err = init_error_state(params)
        step = make_compressed_train_step(m, TrainConfig(), mesh)
        batch = dummy_batch(cfg, 8, 32)
        with mesh:
            lowered = jax.jit(step).lower(state, err, batch)
            compiled = lowered.compile()
            txt = compiled.as_text()
        assert "all-gather" in txt or "all-reduce" in txt
        # int8 payload crosses pods (the compressed wire format)
        assert "s8[" in txt, "expected int8 collective payload"
        state2, err2, metrics = compiled(state, err, batch)
        assert bool(jnp.isfinite(metrics["loss"]))
        print("OK compressed step")
    """)
    assert "OK compressed step" in out


@pytest.mark.slow
def test_pp_loss_lowers_and_differentiates():
    out = _run("""
        import jax, jax.numpy as jnp
        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        from repro.configs import get_smoke_config
        from repro.models.model import build
        from repro.dist.pp import make_pp_loss
        import dataclasses
        # fp32 params: XLA CPU 0.8.x CHECK-crashes in AllReducePromotion on
        # bf16 all-reduces inside manual-axis while loops (TPU unaffected)
        cfg = dataclasses.replace(get_smoke_config("granite-3-2b"),
                                  dtype="float32")  # 2 layers = 2 stages
        m = build(cfg)
        params = m.init(jax.random.PRNGKey(0))
        loss_fn = make_pp_loss(cfg, mesh, n_micro=4)
        toks = jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0,
                                  cfg.vocab_size, dtype=jnp.int32)
        with mesh:
            val_grad = jax.jit(jax.value_and_grad(loss_fn))
            loss, grads = val_grad(params, toks)
        assert bool(jnp.isfinite(loss)), loss
        gn = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
        assert gn > 0
        print("OK pp loss", float(loss))
    """)
    assert "OK pp loss" in out


@pytest.mark.slow
def test_mini_dryrun_decode_cell():
    out = _run("""
        import jax, jax.numpy as jnp
        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        from repro.configs import get_smoke_config
        from repro.configs.base import ShapeConfig
        from repro.dist import sharding as shd
        from repro.launch import specs as S
        from repro.models.model import build
        cfg = get_smoke_config("yi-6b")
        sc = ShapeConfig("d", 64, 16, "decode")
        model = build(cfg, constrain=shd.make_constrain(mesh))
        pspecs = S.param_specs(model, cfg, mesh)
        specs = S.input_specs(model, cfg, sc, mesh)
        def fn(params, cache, tokens, pos):
            return model.decode_step(params, cache, tokens, pos)
        with mesh:
            compiled = jax.jit(fn, donate_argnums=(1,)).lower(
                pspecs, specs["cache"], specs["tokens"], specs["pos"]
            ).compile()
        assert compiled.cost_analysis()["flops"] > 0
        print("OK mini dryrun")
    """)
    assert "OK mini dryrun" in out
