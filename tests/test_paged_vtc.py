"""Block-table + Victima Translation Cache behaviour.

The property-based test degrades gracefully: it importorskips
``hypothesis`` so the deterministic tests in this file run everywhere.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.paged import block_table as btab
from repro.paged import translation_cache as vtc_mod


def test_walk_roundtrip():
    bt = btab.make(4, 256, 32)
    bt = btab.map_block(bt, jnp.int32(1), jnp.int32(130), jnp.int32(77))
    phys, hops, row = btab.walk(bt, jnp.int32(1), jnp.int32(130))
    assert int(phys) == 77 and int(hops) == 2
    phys2, hops2, _ = btab.walk(bt, jnp.int32(1), jnp.int32(131))
    assert int(phys2) == -1  # unmapped sibling in same leaf


def test_unmap_request_clears():
    bt = btab.make(4, 256, 32)
    for b in range(8):
        bt = btab.map_block(bt, jnp.int32(2), jnp.int32(b), jnp.int32(b + 1))
    bt = btab.unmap_request(bt, jnp.int32(2))
    phys, _, _ = btab.walk(bt, jnp.int32(2), jnp.int32(3))
    assert int(phys) == -1
    assert int(jnp.sum(bt.leaf_free)) == 32


def test_vtc_translation_always_correct():
    """Whatever the hit path (TC / cluster / walk), the returned physical
    page must equal the block table's ground truth (property-based)."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @given(st.lists(st.tuples(st.integers(0, 3), st.integers(0, 63)),
                    min_size=1, max_size=60))
    @settings(max_examples=15, deadline=None)
    def check(accesses):
        bt = btab.make(4, 64, 16)
        truth = {}
        rng = np.random.default_rng(0)
        for r in range(4):
            for b in range(64):
                p = int(rng.integers(0, 1 << 15))
                bt = btab.map_block(bt, jnp.int32(r), jnp.int32(b),
                                    jnp.int32(p))
                truth[(r, b)] = p
        vtc = vtc_mod.make(tc_sets=8, tc_ways=2, n_clusters=16)
        for r, b in accesses:
            vtc, bt2, phys, src = vtc_mod.translate(
                vtc, bt, jnp.int32(r), jnp.int32(b), jnp.bool_(True))
            bt = bt2
            assert int(phys) == truth[(r, b)], (r, b, int(src))

    check()


def test_vtc_cluster_hits_after_walks():
    """Hot leaf regions must migrate into cluster pages (the Victima
    effect): repeated walks on a block region → later neighbours hit
    tier 1/2, not the walk path."""
    bt = btab.make(2, 64, 16)
    for b in range(64):
        bt = btab.map_block(bt, jnp.int32(0), jnp.int32(b), jnp.int32(b))
    vtc = vtc_mod.make(tc_sets=4, tc_ways=2, n_clusters=32)
    # touch block 0 repeatedly: counters cross the PTW-CP box
    for _ in range(3):
        vtc, bt, _, _ = vtc_mod.translate(vtc, bt, jnp.int32(0),
                                          jnp.int32(0), jnp.bool_(True))
    # a neighbour in the same 8-block cluster should now avoid the walk
    vtc, bt, phys, src = vtc_mod.translate(vtc, bt, jnp.int32(0),
                                           jnp.int32(3), jnp.bool_(True))
    assert int(phys) == 3
    assert int(src) in (0, 1), "expected TC or cluster hit, got walk"


def test_vtc_shootdown():
    bt = btab.make(2, 64, 16)
    for b in range(8):
        bt = btab.map_block(bt, jnp.int32(1), jnp.int32(b), jnp.int32(b))
    vtc = vtc_mod.make(tc_sets=4, tc_ways=2, n_clusters=32)
    for b in range(8):
        vtc, bt, _, _ = vtc_mod.translate(vtc, bt, jnp.int32(1),
                                          jnp.int32(b), jnp.bool_(True))
    vtc = vtc_mod.invalidate_request(vtc, jnp.int32(1))
    assert int(jnp.sum(vtc.tc_valid)) == 0
    assert int(jnp.sum(vtc.cl_valid)) == 0


def test_engine_lifecycle():
    from repro.serve import engine
    cfg = engine.EngineConfig(n_slots=4, max_blocks_per_req=16,
                              n_pool_pages=128, n_leaf_rows=32,
                              tc_sets=8, tc_ways=2, n_clusters=32)
    st_ = engine.init(cfg)
    st_, ok0 = engine.admit(st_, 0, 2)
    st_, ok1 = engine.admit(st_, 1, 3)
    assert bool(ok0) and bool(ok1)
    free0 = int(jnp.sum(st_.page_free))
    assert free0 == 128 - 5
    for _ in range(10):
        st_, phys, src = engine.decode_translate(st_, cfg)
    s = engine.stats(st_)
    assert s["walk_rate"] < 1.0  # some hits happened
    st_ = engine.retire(st_, 0)
    assert not bool(st_.slot_live[0])
    assert int(jnp.sum(st_.page_free)) > free0
