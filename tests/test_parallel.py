"""shard_map sweep engine: mesh planning + multi-device equivalence.

- ``parallel.plan_mesh`` unit tests: system-axis padding (28 members
  onto 4/6/8 fake devices), workload-axis factorization, the 1x1
  single-device fallback, rejection of empty ladders and bad forced
  meshes;
- bit-identity of the shard_map dispatch vs a forced 1x1 mesh (= plain
  jit(vmap)) vs per-system static ``simulate`` runs on a small
  4-system x 2-workload family — on a multi-device host (the
  ``multidev`` CI job forces 4 via XLA_FLAGS) the auto plan is a real
  mesh, so the comparison pins sharded == unsharded;
- overlapped trace generation (``trace_gen.generate_many``) equals
  serial ``generate`` for every registered workload and seed 0/1/7 —
  seed-stability is what keeps the sim cache valid;
- golden cache-key digests for ``runner._key`` so a ``_canon``/dispatch
  refactor can never silently re-key (and orphan) .sim_cache entries;
- ``runner._stack_traces`` names the mismatched workload instead of
  dying with a KeyError;
- [multidev] ``run_ladder`` on a 4-device mesh writes cache entries
  byte-identical to the forced single-device (1x1 mesh) run.
"""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from golden_trace import GOLDEN_CFG, golden_trace
from repro.core.caches import Lat
from repro.core.mmu import SimConfig, simulate, simulate_systems
from repro.core.stages import default_stages, dyn_of
from repro.sim import parallel

multidev = pytest.mark.multidev


# ------------------------------------------------------------ mesh planning


def test_plan_mesh_pads_system_axis():
    """A 28-member ladder (the native family) lands on 4/6/8 devices by
    PADDING the system axis to a mesh multiple — "S divides evenly" is
    no longer a precondition (the old pmap path silently fell back to
    one device whenever it wasn't)."""
    for d, pad in [(4, 28), (6, 30), (8, 32)]:
        plan = parallel.plan_mesh(28, 11, n_devices=d)
        # 11 workloads are prime and coprime to d, so the wl dim is 1
        assert (plan.sys_dim, plan.wl_dim) == (d, 1), d
        assert plan.pad_systems == pad, d
        assert plan.pad_systems % plan.sys_dim == 0
        assert plan.pad_systems >= plan.n_systems


def test_plan_mesh_shards_workloads_when_divisible():
    plan = parallel.plan_mesh(4, 2, n_devices=4)
    assert (plan.sys_dim, plan.wl_dim) == (2, 2)
    assert plan.pad_systems == 4
    plan = parallel.plan_mesh(5, 4, n_devices=8)
    assert (plan.sys_dim, plan.wl_dim) == (2, 4)
    assert plan.pad_systems == 6


def test_plan_mesh_single_device_is_identity():
    plan = parallel.plan_mesh(28, 11, n_devices=1)
    assert (plan.sys_dim, plan.wl_dim) == (1, 1)
    assert plan.pad_systems == 28  # never pads on a 1x1 mesh
    assert plan.n_devices == 1


def test_plan_mesh_never_outgrows_the_system_axis():
    """An 8-device host must not run a 2-system ladder 4x redundantly:
    the sys dim caps at S (leftover devices simply idle)."""
    plan = parallel.plan_mesh(2, 1, n_devices=8)
    assert plan.sys_dim == 2 and plan.pad_systems == 2


def test_plan_mesh_rejects_empty_ladders():
    with pytest.raises(ValueError, match="empty ladder"):
        parallel.plan_mesh(0, 11)
    with pytest.raises(ValueError, match="empty ladder"):
        parallel.plan_mesh(4, 0)


def test_plan_mesh_forced_mesh_validates():
    plan = parallel.plan_mesh(5, 4, n_devices=8, force=(3, 2))
    assert (plan.sys_dim, plan.wl_dim) == (3, 2)
    assert plan.pad_systems == 6
    # the wl dim must divide W exactly (traces are never padded here)
    with pytest.raises(ValueError, match="does not divide"):
        parallel.plan_mesh(4, 3, force=(2, 2))
    with pytest.raises(ValueError, match=">= 1"):
        parallel.plan_mesh(4, 4, force=(0, 2))


def test_build_mesh_rejects_oversized_plans():
    plan = parallel.plan_mesh(28, 11,
                              n_devices=jax.local_device_count() * 2,
                              force=(jax.local_device_count() * 2, 1))
    with pytest.raises(ValueError, match="devices"):
        parallel.build_mesh(plan)


# ------------------------------------------- shard_map == jit(vmap) == static


_VARIANTS = [
    dict(l2tlb_sets=8, l2tlb_ways=4),
    dict(l2tlb_sets=16, l2tlb_ways=4, victima=True),
    dict(l2tlb_sets=16, l2tlb_ways=8, l2tlb_lat=17),
    dict(l2tlb_sets=8, l2tlb_ways=8, victima=True, l2_sets=32, l2_ways=4),
]


@pytest.fixture(scope="module")
def family_traces():
    tr_a = {k: jnp.asarray(v) for k, v in golden_trace(n=1500).items()}
    tr_b = {k: jnp.asarray(v)
            for k, v in golden_trace(n=1500, seed=777).items()}
    stacked = {k: jnp.stack([tr_a[k], tr_b[k]], axis=1) for k in tr_a}
    return stacked, (tr_a, tr_b)


def _family(variants):
    from repro.sim.systems import dyn_base_config

    cfgs = [dataclasses.replace(GOLDEN_CFG, **v) for v in variants]
    dyns = jax.tree.map(lambda *xs: jnp.stack(xs),
                        *[dyn_of(c) for c in cfgs])
    return dyn_base_config(cfgs), cfgs, dyns


def _assert_same_stats(ref, got, ctx):
    for field, a, b in zip(ref._fields, ref, got):
        assert np.array_equal(np.asarray(a), np.asarray(b)), (ctx, field)


def test_shard_map_matches_jit_vmap_and_static(family_traces):
    """The sharded dispatch is bit-identical to a forced 1x1 mesh (plain
    jit(vmap) semantics) AND to per-system static simulate runs, on a
    4-system x 2-workload family.  Under the multidev CI job the auto
    plan is a real 2x2 mesh, so this pins sharded == unsharded."""
    traces, (tr_a, tr_b) = family_traces
    base, cfgs, dyns = _family(_VARIANTS)
    per, extras = simulate_systems(base, dyns, traces)
    one = parallel.plan_mesh(len(cfgs), 2, n_devices=1)
    per1, _ = simulate_systems(base, dyns, traces, plan=one)
    for si, c in enumerate(cfgs):
        for wi, tr in enumerate((tr_a, tr_b)):
            ref, _ = simulate(c, tr)
            _assert_same_stats(ref, per[si][wi], ("shard", si, wi))
            _assert_same_stats(ref, per1[si][wi], ("1x1", si, wi))
    assert np.all(np.isfinite(np.asarray(
        [extras[si][wi]["l2_access"] for si in range(len(cfgs))
         for wi in range(2)])))


def test_shard_map_pads_odd_system_axis(family_traces):
    """3 systems (odd, prime) through the mesh: on a multi-device host
    the system axis pads up to the mesh and the padding lanes are
    sliced off — results still match static runs bit-for-bit."""
    traces, (tr_a, _) = family_traces
    base, cfgs, dyns = _family(_VARIANTS[:3])
    per, _ = simulate_systems(base, dyns, traces)
    for si, c in enumerate(cfgs):
        ref, _ = simulate(c, tr_a)
        _assert_same_stats(ref, per[si][0], ("pad", si))


# ------------------------------------------------ overlapped trace generation


@pytest.mark.parametrize("seed", [0, 1, 7])
def test_generate_many_matches_serial(seed):
    """The thread-pool generation path must be bit-identical to serial
    ``generate`` for every registered workload — seed-stability is what
    keeps the seed-keyed sim cache valid."""
    from repro.sim import trace_gen

    names = trace_gen.all_workloads()
    par = trace_gen.generate_many(names, n=4000, seed=seed, workers=4)
    assert [g["spec"].name for g in par] == names  # input order kept
    for name, g in zip(names, par):
        ref = trace_gen.generate(name, n=4000, seed=seed)
        assert g["spec"] == ref["spec"]
        assert g["n_pages"] == ref["n_pages"]
        assert g["n_pages_2m_region"] == ref["n_pages_2m_region"]
        for k in ref["trace"]:
            assert np.array_equal(g["trace"][k], ref["trace"][k]), (name, k)


def test_generate_many_empty_and_default_workers():
    from repro.sim import trace_gen

    assert trace_gen.generate_many([]) == []
    got = trace_gen.generate_many(["bc"], n=256, seed=0)
    ref = trace_gen.generate("bc", n=256, seed=0)
    assert np.array_equal(got[0]["trace"]["vpn"], ref["trace"]["vpn"])


# ----------------------------------------------------- golden cache keys


def test_cache_key_golden_digests():
    """Pin ``runner._key`` hex digests: a refactor of ``_canon`` or the
    chunked/meshed dispatch must never silently re-key — and thus
    orphan — existing .sim_cache entries.  Regenerating these constants
    is only legitimate when deliberately invalidating every cache."""
    from repro.sim import runner

    cases = [
        (("radix", "bc", 150_000, 0, None),
         "a12d63c168329072"),
        (("victima", "xs", 150_000, 0, None),
         "35f3abbee2b6e96a"),
        (("np", "rnd", 2_000, 7, {"l2tlb_lat": 17}),
         "bf3ddcef155371f6"),
        # Lat-containing digests regenerated when Lat grew the `dramc`
        # field (die-stacked DRAM cache): a Lat override now keys the
        # new field too.  Deliberate — entries keyed on a Lat override
        # predate the field and must not alias the new latency space.
        (("radix", "gen", 1_000, 1, {"lat": Lat(l2=20)}),
         "93c2444c4c17c805"),
        # numpy scalars key like the equivalent python number
        (("radix", "bc", 10, 0, {"l2_sets": np.int32(64)}),
         "608ce6642b850fb7"),
        (("radix", "bc", 10, 0, {"l2_sets": 64}),
         "608ce6642b850fb7"),
        (("utopia", "dlrm", 150_000, 0,
          {"restseg_ways": jnp.int32(8), "victima": True}),
         "f9fb80121a22570e"),
        (("revelator_virt", "gen", 150_000, 3,
          {"rev_sig_bits": np.int64(16), "lat": Lat()}),
         "80b1083c2726bdbb"),
    ]
    for args, want in cases:
        assert runner._key(*args) == want, args


# ------------------------------------------------- _stack_traces validation


def test_stack_traces_names_the_mismatched_workload():
    """A generator emitting different trace keys used to surface as a
    bare KeyError deep in a dict comprehension; the error must name the
    offending workload and both key sets."""
    from repro.sim import runner, trace_gen

    g_ok = trace_gen.generate("bc", n=64, seed=0)
    g_missing = trace_gen.generate("xs", n=64, seed=0)
    g_missing["trace"].pop("line")
    with pytest.raises(ValueError, match=r"'xs'.*'bc'"):
        runner._stack_traces([g_ok, g_missing], 64)

    g_extra = trace_gen.generate("rnd", n=64, seed=0)
    g_extra["trace"]["bogus"] = g_extra["trace"]["vpn"]
    with pytest.raises(ValueError, match="bogus"):
        runner._stack_traces([g_ok, g_extra], 64)

    stacked = runner._stack_traces(
        [g_ok, trace_gen.generate("xs", n=64, seed=0)], 64)
    assert stacked["vpn"].shape == (64, 2)
    assert stacked["ipa"].shape == (64, 2)


def test_run_ladder_pads_partial_chunks_to_fixed_width(tmp_path,
                                                       monkeypatch):
    """A rerun with fewer missing workloads than the chunk width must
    NOT shrink the dispatch: it pads up to ``chunk`` so the compiled
    [S, chunk] shape is reused, and a forced mesh planned for ``chunk``
    stays valid (a 1-missing rerun under ``--mesh 1x2`` used to die in
    plan_mesh's divisibility check before simulating)."""
    from repro.core.stages import zero_stats
    from repro.sim import runner, systems

    monkeypatch.setattr(systems, "REGISTRY", _tiny_registry())
    monkeypatch.setattr(runner, "CACHE_DIR", str(tmp_path))
    members = ("t_radix", "t_victima")

    widths = []
    runners_built = []

    def fake_make_systems_runner(cfg, plan, stage_names=None, **kwargs):
        runners_built.append(plan)

        def fake_run(dyns, traces):
            S = jax.tree.leaves(dyns)[0].shape[0]
            W = jax.tree.leaves(traces)[0].shape[1]
            widths.append((S, W))
            return ([[zero_stats() for _ in range(W)] for _ in range(S)],
                    [[{} for _ in range(W)] for _ in range(S)])
        return fake_run

    monkeypatch.setattr(runner, "make_systems_runner",
                        fake_make_systems_runner)
    out = runner.run_ladder("tiny", workloads=["bc"], n=64, seed=0,
                            members=members, chunk=4, mesh=(1, 2))
    assert widths == [(2, 4)]  # padded to the chunk, not shrunk to 1
    assert len(runners_built) == 1  # one runner (compile) per fill
    assert (runners_built[0].sys_dim, runners_built[0].wl_dim) == (1, 2)
    assert set(out["t_radix"]) == {"bc"}  # padding lanes never stored
    assert os.path.exists(runner._path("t_victima", "bc", 64, 0, None))
    assert runner.LADDER_PERF[-1]["mesh"] == [1, 2]
    assert runner.LADDER_PERF[-1]["chunk"] == 4


# --------------------------------------------- multidev ladder equivalence


_TINY_OV = dict(
    l2tlb_sets=4, l2tlb_ways=4,
    l1d4_sets=2, l1d4_ways=2, l1d2_sets=2, l1d2_ways=2,
    l2_sets=64, l2_ways=8, l3_sets=64, l3_ways=8,
    n_pages4=1 << 12, n_pages2=1 << 8, n_pagesh=1 << 8, n_feat=1 << 10,
)


def _tiny_registry():
    from repro.sim import systems

    fake = {}
    for name, extra in [("t_radix", {}),
                        ("t_victima", {"victima": True}),
                        ("t_l2tlb", {"l2tlb_sets": 8, "l2tlb_lat": 17})]:
        ov = {**_TINY_OV, **extra}
        cfg = dataclasses.replace(SimConfig(), **ov)
        fake[name] = systems.System(name=name, stages=default_stages(cfg),
                                    overrides=ov)
    return fake


@multidev
def test_run_ladder_multidev_cache_byte_identical(tmp_path, monkeypatch):
    """run_ladder on a >= 4-device mesh must write cache entries
    BYTE-identical to the forced single-device (1x1 mesh) run — the
    acceptance bar for the whole sharded sweep engine.  3 members (odd:
    exercises system padding) x 3 workloads in chunks of 2 (exercises
    chunk padding + multi-chunk pipelining)."""
    if jax.local_device_count() < 4:
        pytest.skip("needs XLA_FLAGS=--xla_force_host_platform_device_count"
                    "=4 (see the multidev CI job)")
    from repro.sim import runner, systems

    monkeypatch.setattr(systems, "REGISTRY", _tiny_registry())
    members = ("t_radix", "t_victima", "t_l2tlb")
    wls, n, seed = ["bc", "xs", "rnd"], 1200, 3

    def fill(cache_dir, mesh):
        monkeypatch.setattr(runner, "CACHE_DIR", str(cache_dir))
        out = runner.run_ladder("tiny", workloads=wls, n=n, seed=seed,
                                members=members, chunk=2, mesh=mesh)
        assert set(out) == set(members)
        return out

    out_multi = fill(tmp_path / "multi", None)       # auto >= 4-dev mesh
    out_single = fill(tmp_path / "single", (1, 1))   # forced 1x1 mesh

    perf = runner.LADDER_PERF[-2:]
    assert perf[0]["mesh"] != [1, 1], "auto plan did not shard"
    assert perf[1]["mesh"] == [1, 1]
    assert all(p["n_chunks"] == 2 for p in perf)

    for s in members:
        for w in wls:
            key = runner._key(s, w, n, seed, None) + ".pkl"
            with open(tmp_path / "multi" / key, "rb") as f:
                blob_m = f.read()
            with open(tmp_path / "single" / key, "rb") as f:
                blob_s = f.read()
            assert blob_m == blob_s, (s, w)
            _assert_same_stats(out_single[s][w][0], out_multi[s][w][0],
                               (s, w))
