"""Checkpoint manager, data pipeline, optimizer, compression numerics."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt.checkpoint import CheckpointManager
from repro.data.pipeline import DataConfig, Pipeline
from repro.optim import adamw


# ------------------------------------------------------------ checkpoint


def _state(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"w": jax.random.normal(k, (8, 8)),
            "opt": {"m": jnp.zeros((8, 8)), "step": jnp.int32(3)}}


def test_ckpt_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep_n=2, async_write=False)
    s = _state()
    mgr.save(10, s)
    restored, step = mgr.restore(s)
    assert step == 10
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(s["w"]))


def test_ckpt_keep_n_and_latest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep_n=2, async_write=False)
    for step in (1, 2, 3, 4):
        mgr.save(step, _state(step))
    assert mgr.all_steps() == [3, 4]
    restored, step = mgr.restore(_state())
    assert step == 4


def test_ckpt_atomic_no_partial(tmp_path):
    """A stray .tmp dir (simulated crash) must be invisible to restore."""
    mgr = CheckpointManager(str(tmp_path), async_write=False)
    mgr.save(5, _state())
    os.makedirs(os.path.join(str(tmp_path), "step_00000009.tmp"))
    assert mgr.latest_step() == 5


def test_ckpt_async(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_write=True)
    mgr.save(7, _state())
    mgr.wait()
    assert mgr.latest_step() == 7


def test_ckpt_digest_detects_corruption(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_write=False)
    mgr.save(1, _state())
    d = os.path.join(str(tmp_path), "step_00000001")
    data = dict(np.load(os.path.join(d, "arrays.npz")))
    data["a0"] = data["a0"] + 1.0
    np.savez(os.path.join(d, "arrays.npz"), **data)
    with pytest.raises(IOError):
        mgr.restore(_state())


# ------------------------------------------------------------ data


def test_data_deterministic():
    cfg = DataConfig(vocab_size=1000, batch=4, seq_len=64, seed=7)
    p = Pipeline(cfg)
    b1 = p.batch_at(13)
    b2 = p.batch_at(13)
    np.testing.assert_array_equal(b1, b2)
    assert b1.shape == (4, 64)
    assert b1.max() < 1000


def test_data_shards_disjoint():
    a = Pipeline(DataConfig(vocab_size=1000, batch=4, seq_len=64,
                            shard_id=0, num_shards=2)).batch_at(3)
    b = Pipeline(DataConfig(vocab_size=1000, batch=4, seq_len=64,
                            shard_id=1, num_shards=2)).batch_at(3)
    assert not np.array_equal(a, b)


def test_data_prefetch_iterator():
    cfg = DataConfig(vocab_size=100, batch=2, seq_len=16)
    p = Pipeline(cfg)
    it = p.iterate(0)
    b0 = next(it)
    next(it)
    p.close()
    np.testing.assert_array_equal(b0, p.batch_at(0))


# ------------------------------------------------------------ optimizer


def test_adamw_minimizes_quadratic():
    cfg = adamw.AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=1,
                            total_steps=200, clip_norm=10.0)
    params = {"x": jnp.asarray([5.0, -3.0])}
    st = adamw.init(params)
    for _ in range(150):
        g = jax.grad(lambda p: jnp.sum(p["x"] ** 2))(params)
        params, st, _ = adamw.update(cfg, g, st, params)
    assert float(jnp.max(jnp.abs(params["x"]))) < 0.1


def test_adamw_clips():
    cfg = adamw.AdamWConfig(clip_norm=1.0)
    params = {"x": jnp.zeros(3)}
    st = adamw.init(params)
    g = {"x": jnp.asarray([100.0, 0.0, 0.0])}
    _, _, m = adamw.update(cfg, g, st, params)
    assert float(m["grad_norm"]) == pytest.approx(100.0)


def test_schedule_warmup_and_decay():
    cfg = adamw.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                            min_lr_frac=0.1)
    assert float(adamw.schedule(cfg, jnp.int32(5))) == pytest.approx(0.5)
    assert float(adamw.schedule(cfg, jnp.int32(10))) == pytest.approx(1.0)
    end = float(adamw.schedule(cfg, jnp.int32(100)))
    assert end == pytest.approx(0.1, abs=1e-3)


# ------------------------------------------------------------ compression


def test_quantize_roundtrip_error_bounded():
    pytest.importorskip("repro.dist", reason="repro.dist not in tree")
    from repro.dist.compress import dequantize, quantize
    x = jax.random.normal(jax.random.PRNGKey(0), (1000,)) * 3
    q, s = quantize(x)
    err = np.asarray(jnp.abs(dequantize(q, s) - x))
    assert err.max() <= float(s) * 0.5 + 1e-6


def test_error_feedback_unbiased_over_steps():
    """Repeatedly EF-compressing the same gradient: the RUNNING MEAN of the
    decoded values converges to the true gradient (bias telescopes)."""
    pytest.importorskip("repro.dist", reason="repro.dist not in tree")
    from repro.dist.compress import dequantize, quantize
    g = jax.random.normal(jax.random.PRNGKey(1), (256,))
    err = jnp.zeros_like(g)
    acc = jnp.zeros_like(g)
    n = 50
    for _ in range(n):
        corrected = g + err
        q, s = quantize(corrected)
        deq = dequantize(q, s)
        err = corrected - deq
        acc = acc + deq
    drift = float(jnp.max(jnp.abs(acc / n - g)))
    assert drift < 5e-3
