"""Flash-attention Pallas kernel vs pure-jnp oracle (shape/dtype sweep)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


def _mk(B, S, H, K, hd, dtype, seed=0):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(k1, (B, S, H, hd), jnp.float32).astype(dtype)
    k = jax.random.normal(k2, (B, S, K, hd), jnp.float32).astype(dtype)
    v = jax.random.normal(k3, (B, S, K, hd), jnp.float32).astype(dtype)
    return q, k, v


@pytest.mark.parametrize("B,S,H,K,hd", [
    (1, 128, 2, 2, 32),
    (2, 256, 4, 2, 64),
    (1, 256, 8, 1, 64),   # MQA
    (2, 128, 6, 3, 16),   # odd group
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_matches_ref(B, S, H, K, hd, dtype, causal):
    q, k, v = _mk(B, S, H, K, hd, dtype)
    o = ops.flash_attention(q, k, v, causal=causal,
                            block_q=128, block_k=128)
    r = ref.mha_reference(jnp.swapaxes(q, 1, 2), jnp.swapaxes(k, 1, 2),
                          jnp.swapaxes(v, 1, 2), causal=causal)
    r = jnp.swapaxes(r, 1, 2)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(r, np.float32),
                               atol=tol, rtol=tol)


@pytest.mark.parametrize("window", [32, 128])
def test_flash_windowed(window):
    q, k, v = _mk(1, 256, 4, 2, 32, jnp.float32)
    o = ops.flash_attention(q, k, v, causal=True, window=window,
                            block_q=64, block_k=64)
    r = ref.mha_reference(jnp.swapaxes(q, 1, 2), jnp.swapaxes(k, 1, 2),
                          jnp.swapaxes(v, 1, 2), causal=True, window=window)
    np.testing.assert_allclose(np.asarray(o),
                               np.asarray(jnp.swapaxes(r, 1, 2)),
                               atol=1e-5, rtol=1e-5)


def test_flash_matches_model_chunked_path():
    """The model's pure-JAX chunked attention (dry-run path) must agree
    with the Pallas kernel — same algorithm, two backends."""
    from repro.models.layers import chunked_attention
    q, k, v = _mk(2, 256, 4, 2, 32, jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(256)[None], (2, 256))
    o1 = chunked_attention(q, k, v, pos, pos, True, None, chunk=64)
    o2 = ops.flash_attention(q, k, v, causal=True, block_q=64, block_k=64)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               atol=1e-5, rtol=1e-5)
