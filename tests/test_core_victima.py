"""Mechanism-level Victima tests on tiny crafted traces (fast configs)."""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import metrics
from repro.core.mmu import SimConfig, simulate

# tiny structures compile in ~20s and exercise every flow
TINY = SimConfig(
    l2tlb_sets=4, l2tlb_ways=4,           # 16-entry L2 TLB
    l1d4_sets=2, l1d4_ways=2, l1d2_sets=2, l1d2_ways=2,
    l2_sets=64, l2_ways=8, l3_sets=64, l3_ways=8,
    n_pages4=1 << 12, n_pages2=1 << 8, n_feat=1,
)


def _trace(vpns, is2m=None):
    n = len(vpns)
    v = np.asarray(vpns, np.int32)
    return {
        "vpn": jnp.asarray(v),
        "is2m": jnp.asarray(np.zeros(n, bool) if is2m is None
                            else np.asarray(is2m, bool)),
        "line": jnp.asarray(v * 64 + (np.arange(n) % 64), np.int32),
        "ipa": jnp.full((n,), 3.0, jnp.float32),
    }


@pytest.fixture(scope="module")
def cyclic_results():
    """A 256-page cyclic sweep: thrashes the 16-entry TLB completely but
    fits easily in Victima's TLB blocks (256/8 = 32 blocks)."""
    pages = np.tile(np.arange(256), 40)
    tr = _trace(pages)
    base, _ = simulate(TINY, tr)
    vic, _ = simulate(dataclasses.replace(TINY, victima=True), tr)
    return base, vic


def test_victima_reduces_ptws(cyclic_results):
    base, vic = cyclic_results
    assert int(base.n_demand_ptw) > 0
    red = metrics.ptw_reduction(base, vic)
    assert red > 0.6, red  # cyclic working set is the ideal case


def test_victima_reduces_miss_latency(cyclic_results):
    base, vic = cyclic_results
    assert metrics.avg_l2tlb_miss_latency(vic) \
        < metrics.avg_l2tlb_miss_latency(base)


def test_victima_hits_accounted(cyclic_results):
    _, vic = cyclic_results
    assert int(vic.n_victima_hit) > 0
    # a victima hit is an L2 TLB miss served without a demand walk
    assert int(vic.n_victima_hit) + int(vic.n_demand_ptw) \
        <= int(vic.n_l2tlb_miss) + 1


def test_reach_counts_blocks(cyclic_results):
    _, vic = cyclic_results
    reach = metrics.translation_reach_mb(vic)
    assert reach > 0
    # can never exceed the whole L2 as TLB blocks (64×8 blocks × 32KB)
    assert reach <= 64 * 8 * 32 / 1024 + 1e-6


def test_virt_victima_kills_host_walks():
    # small L3 so host walks touch DRAM (PTW-CP needs cost ≥ 1 to install
    # nested TLB blocks — with an all-hits cache it rightly stays silent)
    pages = np.tile(np.arange(2048), 4)
    tr = _trace(pages)
    # L3 small enough that host walks touch DRAM (PTW-CP cost bit set),
    # L2 large enough that an installed 8-entry nested block survives the
    # ~7 accesses until its sequential neighbours arrive
    cfgv = dataclasses.replace(TINY, virt=True, l2_sets=64, l3_sets=16)
    base, _ = simulate(cfgv, tr)
    vic, _ = simulate(dataclasses.replace(cfgv, victima=True), tr)
    assert int(base.n_host_ptw) > 0
    # gVA TLB blocks short-circuit the whole 2-D walk, so host walks drop
    # dramatically (the paper's Fig. 28 host-PTW elimination); the nested
    # TLB absorbs most of the residual guest-walk translations
    assert int(vic.n_host_ptw) < 0.3 * int(base.n_host_ptw)
    assert int(vic.n_victima_hit) > 0
    assert int(vic.n_ntlb_hit) + int(vic.n_nvictima_hit) > 0


def test_isp_faster_than_np():
    pages = np.tile(np.arange(512), 10)
    tr = _trace(pages)
    npg, _ = simulate(dataclasses.replace(TINY, virt=True), tr)
    isp, _ = simulate(dataclasses.replace(TINY, virt=True,
                                          ideal_shadow=True), tr)
    assert metrics.avg_l2tlb_miss_latency(isp) \
        < metrics.avg_l2tlb_miss_latency(npg)


def test_2m_pages_walk_shorter():
    pages = np.tile(np.arange(2048), 4)
    tr4 = _trace(pages)
    tr2 = _trace(pages, is2m=np.ones(len(pages), bool))
    w4, _ = simulate(TINY, tr4)
    w2, _ = simulate(TINY, tr2)
    # 2M pages: far fewer walks AND less total walk time (the per-walk
    # average is dominated by cold leaf misses, so compare totals)
    assert int(w2.n_demand_ptw) < int(w4.n_demand_ptw)
    assert float(w2.sum_walk_cyc) < float(w4.sum_walk_cyc)
