"""SSD intra-chunk Pallas kernel vs oracle + model-level consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


@pytest.mark.parametrize("T,q,R,p,n", [
    (2, 32, 4, 16, 16),
    (1, 64, 2, 32, 32),
    (3, 16, 8, 8, 16),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_intra_matches_ref(T, q, R, p, n, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    x = jax.random.normal(ks[0], (T, q, R, p), jnp.float32).astype(dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (T, q, R, 1)))
    dA = -dt * jnp.exp(jax.random.normal(ks[2], (1, 1, R, 1)) * 0.3)
    B = jax.random.normal(ks[3], (T, q, R, n), jnp.float32).astype(dtype)
    C = jax.random.normal(ks[4], (T, q, R, n), jnp.float32).astype(dtype)
    y, S = ops.ssd_intra(x, dt, dA, B, C)
    tol = 5e-2 if dtype == jnp.bfloat16 else 1e-4
    for t in range(T):
        for r in range(R):
            yr, Sr = ref.ssd_intra_reference(
                x[t, :, r], dt[t, :, r, 0], dA[t, :, r, 0],
                B[t, :, r], C[t, :, r])
            np.testing.assert_allclose(np.asarray(y[t, :, r], np.float32),
                                       np.asarray(yr, np.float32),
                                       atol=tol, rtol=tol)
            np.testing.assert_allclose(np.asarray(S[t, r], np.float32),
                                       np.asarray(Sr, np.float32),
                                       atol=tol, rtol=tol)


def test_ssd_chunked_equals_sequential():
    """models.ssm.ssd_chunked must equal a token-by-token recurrence."""
    from repro.models.ssm import ssd_chunked
    b, s, g, r, p, n, chunk = 1, 64, 1, 3, 8, 8, 16
    ks = jax.random.split(jax.random.PRNGKey(3), 5)
    x = jax.random.normal(ks[0], (b, s, g, r, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, g, r)))
    A = -jnp.exp(jax.random.normal(ks[2], (g, r)) * 0.3)
    B = jax.random.normal(ks[3], (b, s, g, n))
    C = jax.random.normal(ks[4], (b, s, g, n))
    y, fstate = ssd_chunked(x, dt, A, B, C, chunk)

    # sequential reference: h_t = exp(dt·A)h + dt·B⊗x ; y = C·h
    h = np.zeros((b, g, r, n, p))
    ys = np.zeros((b, s, g, r, p))
    for t in range(s):
        dA = np.exp(np.asarray(dt[:, t] * A))             # [b,g,r]
        upd = np.einsum("bgn,bgr,bgrp->bgrnp", np.asarray(B[:, t]),
                        np.asarray(dt[:, t]), np.asarray(x[:, t]))
        h = h * dA[..., None, None] + upd
        ys[:, t] = np.einsum("bgn,bgrnp->bgrp", np.asarray(C[:, t]), h)
    np.testing.assert_allclose(np.asarray(y), ys, atol=1e-3, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(fstate), h, atol=1e-3, rtol=1e-3)


def test_rglru_scan_equals_loop():
    """Parallel-prefix RG-LRU must equal the sequential recurrence."""
    from repro.models.rglru import rglru_apply, rglru_init, rglru_step
    from repro.configs import get_smoke_config
    cfg = get_smoke_config("recurrentgemma-2b")
    p = rglru_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.lru_width),
                          jnp.float32)
    y, hlast = rglru_apply(p, x)
    h = jnp.zeros((2, cfg.lru_width))
    ys = []
    for t in range(16):
        out, h = rglru_step(p, x[:, t], h)
        ys.append(out)
    yseq = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yseq),
                               atol=1e-4, rtol=1e-4)
