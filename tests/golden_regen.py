"""Regenerate the golden Stats snapshot.

Usage:  PYTHONPATH=src:tests python -m golden_regen

Only rerun this when the simulator's *intended* behaviour changes; the
whole point of the snapshot is to catch unintended drift during
refactors.
"""
import dataclasses
import json
import os

import jax.numpy as jnp

from golden_trace import (GOLDEN_CFG, GOLDEN_SYSTEMS, golden_trace,
                          stats_to_jsonable)
from repro.core.mmu import simulate

OUT = os.path.join(os.path.dirname(__file__), "golden", "mmu_stats.json")


def main():
    tr = {k: jnp.asarray(v) for k, v in golden_trace().items()}
    snap = {}
    for name, overrides in GOLDEN_SYSTEMS.items():
        cfg = dataclasses.replace(GOLDEN_CFG, **overrides)
        stats, _ = simulate(cfg, tr)
        snap[name] = stats_to_jsonable(stats)
        print(f"[golden] {name}: n_demand_ptw={snap[name]['n_demand_ptw']} "
              f"sum_trans_cyc={snap[name]['sum_trans_cyc']}")
    os.makedirs(os.path.dirname(OUT), exist_ok=True)
    with open(OUT, "w") as f:
        json.dump(snap, f, indent=1, sort_keys=True)
    print(f"[golden] wrote {OUT}")


if __name__ == "__main__":
    main()
