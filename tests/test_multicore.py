"""Multicore MMU: per-core lanes, shared tier, mixes — equivalences.

- n_cores=1 with every multicore knob at its default is the DEGENERATE
  case: Stats bit-identical to the golden snapshot, and single-core
  results keep the exact pre-multicore extras payload (no shared-tier
  keys leak into their sim-cache entries);
- ``generate_mix`` lane c == serial ``generate(names[c % k], n,
  seed + MIX_SEED_SKEW*c)`` leaf-for-leaf (round-robin-with-skew
  arbiter), plus the ``core``/``ipa`` lane leaves and per-core specs;
- vmapped core lanes == per-core static sims bit-for-bit: the core
  axis is just the batch axis, and the shared-tier contention term
  depends only on the lane's own core id;
- ``sweep.parse_args`` rejects unknown mix components and flag-like
  values BEFORE anything compiles; ``--cores`` without a registered
  core count dies in ``main`` before any simulation;
- idle-lane metrics report 0.0 through ``reduction``/``rate`` (the
  max(x, 1) bug class) instead of garbage;
- [multidev] a 3-dim ("sys", "wl", "core") mesh fill writes cache
  entries byte-identical to the forced single-device (1x1x1) run.
"""
import dataclasses
import json
import os
import types

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from golden_trace import GOLDEN_CFG, GOLDEN_SYSTEMS, golden_trace, \
    stats_to_jsonable
from repro.core import metrics
from repro.core.mmu import SimConfig, simulate, simulate_batch
from repro.core.stages import default_stages
from repro.sim import sweep, trace_gen

multidev = pytest.mark.multidev

GOLDEN_PATH = os.path.join(os.path.dirname(__file__), "golden",
                           "mmu_stats.json")

PLAIN_EXTRAS = ["hist_reuse_data", "hist_reuse_tlb", "l2_access", "l2_miss"]
SHARED_EXTRAS = ["dramc_access", "dramc_hit", "l3_access", "l3_trans"]


# -------------------------------------------------- degenerate single-core


def test_degenerate_multicore_matches_golden_snapshot():
    """n_cores=1 + dram_cache_sets=0 (the explicit degenerate multicore
    config) must stay bit-identical to the pre-multicore golden
    snapshot — the whole refactor compiles out."""
    with open(GOLDEN_PATH) as f:
        snap = json.load(f)
    d = SimConfig()
    tr = {k: jnp.asarray(v) for k, v in golden_trace().items()}
    for name, overrides in GOLDEN_SYSTEMS.items():
        cfg = dataclasses.replace(
            GOLDEN_CFG, n_cores=1, shared_port_cyc=d.shared_port_cyc,
            shared_tier_stats=False, dram_cache_sets=0,
            dram_cache_ways=d.dram_cache_ways, **overrides)
        stats, extras = simulate(cfg, tr)
        got = stats_to_jsonable(stats)
        for field, want in snap[name].items():
            assert got[field] == want, (name, field)
        # single-core extras payload unchanged: shared-tier keys must
        # NOT leak in, or every existing cache entry re-pickles dirty
        assert sorted(extras) == PLAIN_EXTRAS, sorted(extras)


def test_shared_tier_stats_opt_in_extras():
    """shared_tier_stats=True surfaces the shared-tier counters even on
    one core (the 1c multicore family uses this for apples-to-apples
    scaling rows) without touching the plain keys."""
    cfg = dataclasses.replace(GOLDEN_CFG, shared_tier_stats=True)
    tr = {k: jnp.asarray(v) for k, v in golden_trace(n=2000).items()}
    _, extras = simulate(cfg, tr)
    assert sorted(extras) == sorted(PLAIN_EXTRAS + SHARED_EXTRAS)
    assert extras["l3_access"] > 0
    assert 0 <= extras["l3_trans"] <= extras["l3_access"]
    assert extras["dramc_access"] == 0  # dram cache compiled out


# -------------------------------------------------------------- mix arbiter


def test_generate_mix_matches_serial_generate():
    """Lane c of a mix == serial generate of its round-robin-assigned
    workload under the per-core skewed seed, leaf-for-leaf."""
    spec, n, seed, cores = "bc+rnd+xs", 512, 5, 4
    names = trace_gen.parse_mix(spec)
    g = trace_gen.generate_mix(spec, n=n, seed=seed, n_cores=cores)
    assert len(g["spec"]) == cores
    for c in range(cores):
        want_name = names[c % len(names)]
        ref = trace_gen.generate(want_name, n=n,
                                 seed=seed + trace_gen.MIX_SEED_SKEW * c)
        for k, v in ref["trace"].items():
            assert np.array_equal(np.asarray(g["trace"][k][:, c]),
                                  np.asarray(v)), (c, k)
        assert g["spec"][c] == ref["spec"], c
        assert np.all(np.asarray(g["trace"]["core"][:, c]) == c)
        assert np.allclose(np.asarray(g["trace"]["ipa"][:, c]),
                           ref["spec"].ipa)


def test_generate_mix_seed_stable():
    a = trace_gen.generate_mix("bc+rnd", n=256, seed=9, n_cores=2)
    b = trace_gen.generate_mix("bc+rnd", n=256, seed=9, n_cores=2)
    for k in a["trace"]:
        assert np.array_equal(np.asarray(a["trace"][k]),
                              np.asarray(b["trace"][k])), k


def test_parse_mix_validation():
    assert trace_gen.parse_mix("bc+rnd+xs") == ["bc", "rnd", "xs"]
    assert trace_gen.parse_mix("bc") == ["bc"]
    with pytest.raises(ValueError, match="unknown workload.*bogus"):
        trace_gen.parse_mix("bc+bogus")
    with pytest.raises(ValueError, match="malformed"):
        trace_gen.parse_mix("bc++rnd")


# ------------------------------------------------ vmapped-core equivalence


def test_vmapped_cores_match_per_core_static_sims():
    """The core axis is the batch axis: each lane of a 2-core mix sim
    must be bit-identical to a static single-trace sim of the same
    2-core config fed that lane's trace (incl. its core-id leaf, which
    the shared-port contention term reads)."""
    cfg = dataclasses.replace(GOLDEN_CFG, n_cores=2,
                              shared_tier_stats=True)
    g = trace_gen.generate_mix("bc+rnd", n=1200, seed=3, n_cores=2)
    stacked = {k: jnp.asarray(v) for k, v in g["trace"].items()}
    per, extras = simulate_batch(cfg, stacked)
    assert len(per) == 2
    for c in range(2):
        lane = {k: v[:, c] for k, v in stacked.items()}
        ref_stats, ref_extras = simulate(cfg, lane)
        for field, a, b in zip(ref_stats._fields, ref_stats, per[c]):
            assert np.array_equal(np.asarray(a), np.asarray(b)), (c, field)
        assert sorted(extras[c]) == sorted(ref_extras), c
        for k in ref_extras:
            assert np.array_equal(np.asarray(extras[c][k]),
                                  np.asarray(ref_extras[k])), (c, k)


def test_contention_differs_across_core_lanes():
    """The shared-port queueing term depends on the lane's core id, so
    two lanes running the SAME workload under n_cores=2 must diverge —
    otherwise the contention model compiled out."""
    cfg = dataclasses.replace(GOLDEN_CFG, n_cores=2)
    g = trace_gen.generate_mix("bc+bc", n=1200, seed=3, n_cores=2)
    stacked = {k: jnp.asarray(v) for k, v in g["trace"].items()}
    per, _ = simulate_batch(cfg, stacked)
    a, b = (int(np.asarray(p.sum_trans_cyc)) for p in per)
    assert a != b, "core-id-dependent contention term had no effect"


# ----------------------------------------------------------- CLI validation


def test_sweep_rejects_unknown_mix_components():
    with pytest.raises(SystemExit, match="unknown workload.*bogus"):
        sweep.parse_args(["--mix", "bc+bogus"])
    with pytest.raises(SystemExit, match="unknown workload"):
        sweep.parse_args(["--mix=rnd+nope+xs"])


def test_sweep_mix_flag_swallowing():
    """`--mix --tags` must not swallow the next option as a mix spec."""
    with pytest.raises(SystemExit, match="--mix needs"):
        sweep.parse_args(["--mix", "--tags"])
    with pytest.raises(SystemExit, match="--mix needs"):
        sweep.parse_args(["--mix"])


def test_sweep_cores_flag_validation():
    with pytest.raises(SystemExit, match="positive integer"):
        sweep.parse_args(["--cores", "x"])
    with pytest.raises(SystemExit, match="positive integer"):
        sweep.parse_args(["--cores=0"])
    # an unregistered core count dies in main BEFORE any simulation
    with pytest.raises(SystemExit, match="core counts: 1, 2, 4"):
        sweep.main(["--cores", "3"])
    names, tags, opts = sweep.parse_args(
        ["--cores", "4", "--mix", "bc+rnd+xs", "--mix=dlrm+gen"])
    assert opts["cores"] == 4
    assert opts["mix"] == ["bc+rnd+xs", "dlrm+gen"]


def test_sweep_mesh_accepts_core_dim():
    _, _, opts = sweep.parse_args(["--mesh", "1x2x2"])
    assert opts["mesh"] == (1, 2, 2)
    with pytest.raises(SystemExit, match="SYSxWL"):
        sweep.parse_args(["--mesh", "1x2x2x2"])


# -------------------------------------------------------- idle-lane metrics


def test_idle_lane_metrics_report_zero():
    """Per-core rate/reduction metrics route through the guarded
    reduction()/rate() helpers: an idle lane (zero baseline events)
    reports exactly 0.0, not max(x, 1)-style garbage."""
    assert metrics.reduction(0, 7) == 0.0
    assert metrics.rate(5, 0) == 0.0
    assert metrics.rate(3, 6) == 0.5
    idle = types.SimpleNamespace(n_demand_ptw=0)
    busy = types.SimpleNamespace(n_demand_ptw=100)
    new = types.SimpleNamespace(n_demand_ptw=50)
    per = metrics.per_core_ptw_reduction((busy, idle), (new, idle))
    assert per == [0.5, 0.0]
    assert metrics.mean_ptw_reduction((busy, idle), (new, idle)) == 0.25
    assert metrics.mean_ptw_reduction((), ()) == 0.0
    assert metrics.l3_translation_share({}) == 0.0
    assert metrics.l3_translation_share(
        {"l3_access": 10, "l3_trans": 4}) == 0.4
    assert metrics.dramc_hit_rate({"dramc_access": 0, "dramc_hit": 0}) == 0.0


# --------------------------------------------- multidev 3-dim mesh ladder


_TINY_OV = dict(
    l2tlb_sets=4, l2tlb_ways=4,
    l1d4_sets=2, l1d4_ways=2, l1d2_sets=2, l1d2_ways=2,
    l2_sets=64, l2_ways=8, l3_sets=64, l3_ways=8,
    n_pages4=1 << 12, n_pages2=1 << 8, n_pagesh=1 << 8, n_feat=1 << 10,
)


def _tiny_mc_registry():
    from repro.sim import systems

    fake = {}
    for name, extra in [("t_radix_2c", {}),
                        ("t_victima_2c", {"victima": True})]:
        ov = {**_TINY_OV, **extra, "n_cores": 2, "shared_tier_stats": True}
        cfg = dataclasses.replace(SimConfig(), **ov)
        fake[name] = systems.System(name=name, stages=default_stages(cfg),
                                    overrides=ov)
    return fake


@multidev
def test_run_ladder_3dim_mesh_cache_byte_identical(tmp_path, monkeypatch):
    """A multicore ladder fill on a ("sys", "wl", "core") mesh must
    write cache entries byte-identical to the forced single-device
    (1x1x1) run — the core axis shards like any other batch axis."""
    if jax.local_device_count() < 4:
        pytest.skip("needs XLA_FLAGS=--xla_force_host_platform_device_count"
                    "=4 (see the multidev CI job)")
    from repro.sim import runner, systems

    monkeypatch.setattr(systems, "REGISTRY", _tiny_mc_registry())
    members = ("t_radix_2c", "t_victima_2c")
    mixes, n, seed = ["bc+rnd", "xs+gen"], 800, 3

    def fill(cache_dir, mesh):
        monkeypatch.setattr(runner, "CACHE_DIR", str(cache_dir))
        out = runner.run_ladder("tiny2c", workloads=mixes, n=n, seed=seed,
                                members=members, chunk=2, mesh=mesh)
        assert set(out) == set(members)
        return out

    out_multi = fill(tmp_path / "multi", (1, 2, 2))
    out_single = fill(tmp_path / "single", (1, 1, 1))

    perf = runner.LADDER_PERF[-2:]
    assert perf[0]["mesh"] == [1, 2, 2]
    # core_dim == 1 keeps the 2-element mesh form (schema compatibility)
    assert perf[1]["mesh"] == [1, 1]
    assert all(p["cores"] == 2 for p in perf)

    for s in members:
        for w in mixes:
            key = runner._key(s, w, n, seed, None) + ".pkl"
            with open(tmp_path / "multi" / key, "rb") as f:
                blob_m = f.read()
            with open(tmp_path / "single" / key, "rb") as f:
                blob_s = f.read()
            assert blob_m == blob_s, (s, w)
            stats_m, extras_m, specs = out_multi[s][w]
            stats_s, _, _ = out_single[s][w]
            assert len(stats_m) == len(stats_s) == 2
            assert tuple(sp.name for sp in specs) == tuple(w.split("+"))
            for c, (a, b) in enumerate(zip(stats_m, stats_s)):
                for field, x, y in zip(a._fields, a, b):
                    assert np.array_equal(np.asarray(x), np.asarray(y)), \
                        (s, w, c, field)
            for c in range(2):
                assert extras_m[c]["l3_access"] > 0, (s, w, c)
