"""Fault-tolerance: checkpoint/restart, failure injection, determinism."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.data.pipeline import DataConfig, Pipeline
from repro.models.model import build
from repro.optim import adamw
from repro.train.train_step import TrainConfig, init_state, make_train_step
from repro.train.trainer import LoopConfig, Trainer


def _setup(tmp_path, total_steps=12, ckpt_every=4):
    cfg = get_smoke_config("granite-3-2b")
    m = build(cfg)
    params = m.init(jax.random.PRNGKey(0))
    state = init_state(params)
    tcfg = TrainConfig(opt=adamw.AdamWConfig(
        lr=3e-3, warmup_steps=2, total_steps=total_steps))
    step_fn = jax.jit(make_train_step(m, tcfg))
    data = Pipeline(DataConfig(vocab_size=cfg.vocab_size, batch=4,
                               seq_len=32, seed=1))
    def batch_fn(s):
        return {"tokens": jnp.asarray(data.batch_at(s))}
    loop = LoopConfig(total_steps=total_steps, ckpt_every=ckpt_every,
                      ckpt_dir=str(tmp_path), log_every=1000)
    return state, step_fn, batch_fn, loop


def test_loss_decreases(tmp_path):
    state, step_fn, batch_fn, loop = _setup(tmp_path, total_steps=15)
    tr = Trainer(step_fn, batch_fn, loop)
    state, hist = tr.run(state)
    assert hist[-1] < hist[0], (hist[0], hist[-1])


def test_fault_injection_recovers(tmp_path):
    state, step_fn, batch_fn, loop = _setup(tmp_path, total_steps=12,
                                            ckpt_every=3)
    fired = {"n": 0}

    def fault(step):
        if step == 7 and fired["n"] == 0:
            fired["n"] = 1
            raise RuntimeError("injected node failure")

    tr = Trainer(step_fn, batch_fn, loop, fault_hook=fault)
    state, hist = tr.run(state)
    assert fired["n"] == 1
    assert tr.n_restarts == 1
    assert tr.ckpt.latest_step() == 12


def test_restart_is_deterministic(tmp_path):
    """Crash + resume must produce the same final params as an
    uninterrupted run (same data replay, same updates)."""
    s1, step_fn, batch_fn, loop1 = _setup(tmp_path / "a", total_steps=8,
                                          ckpt_every=2)
    tr1 = Trainer(step_fn, batch_fn, loop1)
    f1, _ = tr1.run(s1)

    s2, step_fn2, batch_fn2, loop2 = _setup(tmp_path / "b", total_steps=8,
                                            ckpt_every=2)

    def fault(step):
        if step == 5 and not getattr(fault, "hit", False):
            fault.hit = True
            raise RuntimeError("boom")

    tr2 = Trainer(step_fn2, batch_fn2, loop2, fault_hook=fault)
    f2, _ = tr2.run(s2)
    d = jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                           - b.astype(jnp.float32)))),
        f1.params, f2.params)
    assert max(jax.tree.leaves(d)) < 1e-5


def test_elastic_reshard_roundtrip(tmp_path):
    """Checkpoints restore onto a different mesh layout (elastic)."""
    from repro.ckpt.checkpoint import CheckpointManager
    from repro.launch.mesh import make_host_mesh
    from jax.sharding import NamedSharding, PartitionSpec as P
    mgr = CheckpointManager(str(tmp_path), async_write=False)
    state = {"w": jnp.arange(16.0).reshape(4, 4)}
    mgr.save(1, state)
    mesh = make_host_mesh()
    specs = {"w": jax.ShapeDtypeStruct(
        (4, 4), jnp.float32,
        sharding=NamedSharding(mesh, P("data", None)))}
    restored, step = mgr.restore_resharded(specs)
    assert step == 1
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(state["w"]))
    assert restored["w"].sharding.spec == P("data", None)
