"""Serving load harness + the engine bugs it exposed.

Regression coverage for the production-traffic fixes: admissions/growth
must never alias pages under pool exhaustion, dead slots must stay out
of the translation batch, the pressure signal must decay with the
working set (epoch window, not lifetime counters), the VTC index
geometry must be validated up front (n_clusters=1 remains the valid
ablation), and the harness's BENCH_serve records must re-derive
bit-exactly from the obs trace.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.obs as obs
from repro.obs import report
from repro.paged import block_table as btab
from repro.paged import translation_cache as vtc_mod
from repro.serve import engine, load
from repro.sim import parallel


@pytest.fixture
def tr(tmp_path):
    t = obs.configure(str(tmp_path / "trace.jsonl"))
    yield t
    obs.configure()


def _mapped_pages(st):
    """Every physical page reachable from the block tables (host list)."""
    rows = np.asarray(st.bt.directory)
    leaves = np.asarray(st.bt.leaves)
    pages = []
    for r in range(rows.shape[0]):
        for row in rows[r]:
            if row >= 0:
                pages += [int(p) for p in leaves[row] if p >= 0]
    return pages


def _assert_no_aliasing(st):
    pages = _mapped_pages(st)
    assert len(pages) == len(set(pages)), (
        f"physical page mapped twice: {sorted(pages)}")
    # and the free vector agrees with the mapping
    assert int(jnp.sum(st.page_free)) == st.page_free.shape[0] - len(pages)


# ------------------------------------------- pool exhaustion (no alias)


def test_admit_rejects_on_pool_exhaustion_without_aliasing():
    cfg = engine.EngineConfig(n_slots=4, max_blocks_per_req=8,
                              n_pool_pages=8, n_leaf_rows=16,
                              tc_sets=8, tc_ways=2, n_clusters=16)
    st = engine.init(cfg)
    st, ok0 = engine.admit(st, 0, 6)
    assert bool(ok0)
    before = jax.device_get(st)
    # only 2 pages left: a 5-page admission must be rejected ATOMICALLY
    st, ok1 = engine.admit(st, 1, 5)
    assert not bool(ok1)
    assert not bool(st.slot_live[1]) and int(st.slot_len[1]) == 0
    assert int(jnp.sum(st.page_free)) == 2  # nothing leaked
    np.testing.assert_array_equal(np.asarray(st.page_free),
                                  np.asarray(before.page_free))
    _assert_no_aliasing(st)
    # a request that still fits is admitted fine afterwards
    st, ok2 = engine.admit(st, 2, 2)
    assert bool(ok2)
    _assert_no_aliasing(st)
    # degenerate requests are rejected too
    st, ok3 = engine.admit(st, 3, 0)
    assert not bool(ok3)


def test_decode_grow_stalls_when_pool_exhausted():
    cfg = engine.EngineConfig(n_slots=2, max_blocks_per_req=8,
                              n_pool_pages=4, n_leaf_rows=16,
                              tc_sets=8, tc_ways=2, n_clusters=16)
    st = engine.init(cfg)
    st, ok = engine.admit(st, 0, 4)     # consumes the whole pool
    assert bool(ok) and int(jnp.sum(st.page_free)) == 0
    len0 = int(st.slot_len[0])
    # pos % TOKENS_PER_PAGE == 0 -> the tick wants to grow a page, but
    # none is free: the slot must STALL (src -1, no advance), not map
    # argmax(all-zero) == page 0 on top of request 0's first block
    st, phys, src = engine.decode_translate(st, cfg)
    assert int(src[0]) == -1
    assert int(st.slot_len[0]) == len0
    assert int(st.n_pool_stall) == 1
    _assert_no_aliasing(st)
    assert engine.stats(st, scope="stall_t")["pool_stall"] == 1
    # freeing pages (retirement) unblocks the next tick
    st = engine.retire(st, 0, scope="stall_t")
    st, ok = engine.admit(st, 0, 2)
    st, phys, src = engine.decode_translate(st, cfg)
    assert int(src[0]) >= 0
    _assert_no_aliasing(st)


# ------------------------------------------------- dead-slot masking


def test_dead_slots_never_enter_translation_batch():
    cfg = engine.EngineConfig(n_slots=4, max_blocks_per_req=8,
                              n_pool_pages=64, n_leaf_rows=32,
                              tc_sets=8, tc_ways=2, n_clusters=16)
    st = engine.init(cfg)
    # no live slots: ticks must touch NO VTC state and no pressure window
    for _ in range(10):
        st, phys, src = engine.decode_translate(st, cfg)
        assert all(int(x) == -1 for x in src)
    v = vtc_mod.stats(st.vtc)
    assert v["n_hit_tc"] == v["n_hit_cluster"] == v["n_walk"] == 0
    assert int(st.win_total) == 0 and not bool(st.pressure)


def test_translation_counts_match_per_live_slot_reference():
    """Stats parity pin: with 2 of 4 slots live, the lifetime VTC counter
    total must equal exactly the per-live-slot stream count (3 lanes per
    live slot per tick) — dead slots contribute nothing."""
    cfg = engine.EngineConfig(n_slots=4, max_blocks_per_req=8,
                              n_pool_pages=64, n_leaf_rows=32,
                              tc_sets=8, tc_ways=2, n_clusters=16)
    st = engine.init(cfg)
    st, _ = engine.admit(st, 0, 2)
    st, _ = engine.admit(st, 2, 3)
    ticks = 9
    for _ in range(ticks):
        st, phys, src = engine.decode_translate(st, cfg)
        assert int(src[1]) == -1 and int(src[3]) == -1
        assert int(src[0]) >= 0 and int(src[2]) >= 0
    v = vtc_mod.stats(st.vtc)
    assert v["n_hit_tc"] + v["n_hit_cluster"] + v["n_walk"] == 6 * ticks


def test_translate_batch_valid_mask_is_inert():
    bt = btab.make(2, 64, 16)
    for b in range(4):
        bt = btab.map_block(bt, jnp.int32(0), jnp.int32(b), jnp.int32(b + 9))
    vtc = vtc_mod.make(tc_sets=8, tc_ways=2, n_clusters=16)
    reqs = jnp.array([0, 0], jnp.int32)
    blks = jnp.array([1, 2], jnp.int32)
    valid = jnp.array([True, False])
    v1, b1, phys, src = vtc_mod.translate_batch(
        vtc, bt, reqs, blks, jnp.bool_(False), valid=valid)
    assert int(phys[0]) == 10 and int(src[0]) >= 0
    assert int(phys[1]) == -1 and int(src[1]) == -1
    # the masked lane left EXACTLY the state the unmasked prefix built
    v2, b2, _, _ = vtc_mod.translate(vtc, bt, jnp.int32(0), jnp.int32(1),
                                     jnp.bool_(False))
    for a, b in zip(jax.tree.leaves(v1), jax.tree.leaves(v2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(jax.tree.leaves(b1), jax.tree.leaves(b2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ------------------------------------------------- windowed pressure


def test_pressure_decays_after_working_set_shrinks():
    cfg = engine.EngineConfig(n_slots=2, max_blocks_per_req=8,
                              n_pool_pages=64, n_leaf_rows=32,
                              tc_sets=4, tc_ways=2, n_clusters=16,
                              pressure_epoch=8, pressure_thresh=0.15)
    st = engine.init(cfg)
    # phase 1 — churn: admit/tick/retire so every tick translates cold
    # (retirement shoots down the VTC): walk-heavy windows latch pressure
    for _ in range(40):
        st, ok = engine.admit(st, 0, 2)
        assert bool(ok)
        st, _, _ = engine.decode_translate(st, cfg)
        st = engine.retire(st, 0, scope="decay_t")
    assert bool(st.pressure), "walk-heavy churn must latch pressure"
    # phase 2 — the working set shrinks to one hot request: the sampled
    # window sees mostly TC hits and the NEXT epoch boundary must drop
    # pressure, even though the lifetime walk rate stays above threshold
    st, ok = engine.admit(st, 0, 2)
    for _ in range(24):
        st, _, _ = engine.decode_translate(st, cfg)
    assert not bool(st.pressure), "pressure must decay with the workload"
    v = vtc_mod.stats(st.vtc)
    assert v["walk_rate"] > cfg.pressure_thresh, (
        "regression guard is vacuous: lifetime counters would have "
        "decayed on their own")


# ------------------------------------- index-geometry validation


def test_vtc_make_rejects_non_pow2_geometry():
    with pytest.raises(ValueError, match="tc_sets"):
        vtc_mod.make(tc_sets=12, tc_ways=2, n_clusters=16)
    with pytest.raises(ValueError, match="n_clusters"):
        vtc_mod.make(tc_sets=8, tc_ways=2, n_clusters=3)
    with pytest.raises(ValueError, match="tc_ways"):
        vtc_mod.make(tc_sets=8, tc_ways=0, n_clusters=16)


def test_engine_config_rejects_bad_geometry():
    with pytest.raises(ValueError, match="tc_sets"):
        engine.EngineConfig(tc_sets=12)
    with pytest.raises(ValueError, match="n_clusters"):
        engine.EngineConfig(n_clusters=24)
    with pytest.raises(ValueError, match="pressure_epoch"):
        engine.EngineConfig(pressure_epoch=0)
    with pytest.raises(ValueError, match="gate"):
        engine.EngineConfig(gate_freq_min=-1)


def test_n_clusters_one_is_the_valid_ablation():
    bt = btab.make(2, 64, 16)
    for b in range(8):
        bt = btab.map_block(bt, jnp.int32(0), jnp.int32(b), jnp.int32(b + 3))
    vtc = vtc_mod.make(tc_sets=4, tc_ways=2, n_clusters=1)
    for b in list(range(8)) * 2:
        vtc, bt, phys, src = vtc_mod.translate(
            vtc, bt, jnp.int32(0), jnp.int32(b), jnp.bool_(True))
        assert int(phys) == b + 3
    # and the engine runs end-to-end on the ablation config
    cfg = engine.EngineConfig(n_slots=2, max_blocks_per_req=8,
                              n_pool_pages=32, n_leaf_rows=16,
                              tc_sets=8, tc_ways=2, n_clusters=1)
    st = engine.init(cfg)
    st, _ = engine.admit(st, 0, 2)
    for _ in range(4):
        st, phys, src = engine.decode_translate(st, cfg)
    assert int(src[0]) >= 0


# --------------------------------------------------- arrival traces


def test_arrival_traces_respect_mix_and_capacity():
    cfg = engine.EngineConfig(n_slots=4, max_blocks_per_req=8,
                              n_pool_pages=64, n_leaf_rows=32)
    cap = cfg.max_blocks_per_req - 1
    for trace in (load.poisson_trace(2.0, 40, cfg, seed=3),
                  load.diurnal_trace(2.0, 40, cfg, seed=3)):
        assert trace, "a 2 req/tick trace over 40 ticks cannot be empty"
        for r in trace:
            assert 0 <= r.arrive_tick < 40
            assert 1 <= r.prompt_blocks <= cap
            assert r.decode_tokens >= 1
            assert r.kind in load.MIX_WEIGHTS
    # determinism: same seed, same trace
    a = load.poisson_trace(1.0, 20, cfg, seed=5)
    b = load.poisson_trace(1.0, 20, cfg, seed=5)
    assert a == b


def test_length_mix_spans_short_and_long_requests():
    cfg = engine.EngineConfig()
    mix = load.length_mix(cfg)
    blocks = sorted(m[1] for m in mix)
    assert blocks[0] < blocks[-1]  # 4K chat << 500K long-context
    assert blocks[-1] <= cfg.max_blocks_per_req - 1


# ------------------------------------------------------ lane sharding


def test_plan_lane_dim_divisor_rule():
    assert parallel.plan_lane_dim(4, n_devices=1) == 1
    assert parallel.plan_lane_dim(4, n_devices=2) == 2
    assert parallel.plan_lane_dim(4, n_devices=3) == 2
    assert parallel.plan_lane_dim(6, n_devices=4) == 3
    assert parallel.plan_lane_dim(3, n_devices=2) == 1
    with pytest.raises(ValueError):
        parallel.plan_lane_dim(0)


def test_shard_lanes_runs_fn_per_lane():
    fn = jax.vmap(lambda x: x * 2 + 1)
    call = parallel.shard_lanes(fn, 4)
    out = call(jnp.arange(4, dtype=jnp.int32).reshape(4, 1))
    np.testing.assert_array_equal(np.asarray(out).ravel(),
                                  np.array([1, 3, 5, 7]))
    assert jax.local_device_count() % call.mesh_dim == 0


# ------------------------------------------------- harness round trip


def test_run_load_round_trip_bit_exact(tr, tmp_path):
    import json

    from repro.obs.__main__ import main
    cfg = engine.EngineConfig(n_slots=4, max_blocks_per_req=8,
                              n_pool_pages=64, n_leaf_rows=32,
                              tc_sets=8, tc_ways=2, n_clusters=16)
    trace = load.poisson_trace(1.0, 25, cfg, seed=11)
    before = len(load.SERVE_PERF)
    rec = load.run_load(trace, cfg, lanes=1, run="rt_test",
                        arrival="poisson", rate=1.0)
    assert len(load.SERVE_PERF) == before + 1
    assert set(rec) == set(report.SERVE_FIELDS)
    assert rec["run"] == "rt_test" and rec["n_arrivals"] == len(trace)
    assert rec["admitted"] == rec["retired"] == len(trace)
    assert rec["decode_p50_s"] > 0 and rec["decode_p99_s"] >= rec["decode_p50_s"]
    assert rec["throughput_rps"] > 0
    assert 0.0 <= rec["vtc_hit_rate"] <= 1.0
    assert rec["vtc_hit_tc"] + rec["vtc_hit_cluster"] + rec["vtc_walk"] > 0
    # offline reconstruction from the JSONL file is bit-exact
    tr.flush()
    offline = report.serve_record(report.read_trace(tr.path),
                                  trace_file=tr.path)
    assert offline == rec
    # and the CLI check agrees against a written artifact
    art = tmp_path / "BENCH_serve.json"
    art.write_text(json.dumps({"schema": 1, "serve_runs": [rec]}))
    assert main(["report", tr.path, "--check", str(art)]) == 0
    doctored = dict(rec, retired=rec["retired"] + 1)
    art.write_text(json.dumps({"schema": 1, "serve_runs": [doctored]}))
    assert main(["report", tr.path, "--check", str(art)]) == 1


def test_run_load_backpressure_requeues_rejections(tr):
    """A pool-starved engine must reject, re-queue, and still finish
    every request — with the rejections visible in the record."""
    cfg = engine.EngineConfig(n_slots=4, max_blocks_per_req=8,
                              n_pool_pages=14, n_leaf_rows=32,
                              tc_sets=8, tc_ways=2, n_clusters=16)
    reqs = [load.Request(0, 4, 2, "train_4k") for _ in range(6)]
    rec = load.run_load(reqs, cfg, lanes=1, run="bp_test",
                        arrival="burst", rate=6.0)
    assert rec["rejected"] > 0
    assert rec["retired"] == len(reqs)
    assert rec["admitted"] == len(reqs)


def test_run_load_two_lanes(tr):
    cfg = engine.EngineConfig(n_slots=2, max_blocks_per_req=8,
                              n_pool_pages=32, n_leaf_rows=16,
                              tc_sets=8, tc_ways=2, n_clusters=16)
    trace = load.poisson_trace(1.0, 15, cfg, seed=4)
    rec = load.run_load(trace, cfg, lanes=2, run="lanes_test",
                        arrival="poisson", rate=2.0)
    assert rec["lanes"] == 2
    assert rec["retired"] == len(trace)
    assert jax.local_device_count() % rec["mesh"] == 0


# ------------------------------------------------------- gate tuning


def test_tune_gate_maps_box_lower_edges(monkeypatch):
    from repro.core import ptwcp_nn
    from repro.sim import runner
    monkeypatch.setattr(
        runner, "run_batch",
        lambda system, workloads, n: {w: (None, {"feat": w}, None)
                                      for w in workloads})
    monkeypatch.setattr(
        ptwcp_nn, "build_dataset",
        lambda extras: (np.zeros((4, 2)), np.zeros(4)))
    monkeypatch.setattr(ptwcp_nn, "fit_box",
                        lambda X, y: (3, 12, 2, 9))  # clo, chi, flo, fhi
    assert load.tune_gate(n=10) == (2, 3)
    # refit edges beyond the counters' saturation range are clamped
    monkeypatch.setattr(ptwcp_nn, "fit_box",
                        lambda X, y: (99, 120, 50, 90))
    assert load.tune_gate(n=10) == (7, 15)


# ---------------------------------------------------- OB001 closure


def test_ob001_serve_contract_clean():
    from repro.analysis import obs_contract
    assert obs_contract.check_serve_field_sources() == []
    assert obs_contract.check_load_appends() == []


def test_ob001_catches_hand_assembled_serve_record(tmp_path):
    from repro.analysis import obs_contract
    bad = tmp_path / "load.py"
    bad.write_text(
        "import repro.obs as obs\n"
        "from repro.obs import names\n"
        "SERVE_PERF = []\n"
        "def run_load():\n"
        "    with obs.span(names.SPAN_SERVE_RUN, run='x') as run_span:\n"
        "        pass\n"
        "    SERVE_PERF.append({'run': 'x'})\n")
    findings = obs_contract.check_load_appends(str(bad))
    assert findings and "hand-assembled" in findings[0]
