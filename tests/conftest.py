import os

# smoke tests and benches must see ONE device — the 512-device override is
# strictly dryrun.py-local (assignment requirement).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402  (env vars above must be set before jax imports)

jax.config.update("jax_compilation_cache_dir",
                  os.environ.get("JAX_CACHE", "/root/repo/.jax_cache"))
jax.config.update("jax_persistent_cache_min_compile_time_secs", 5)


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "slow: long-running tests (multi-device lowering subprocesses); "
        "deselect with -m 'not slow'")
    config.addinivalue_line(
        "markers",
        "multidev: tests that need a sharded ('sys', 'wl') device mesh; "
        "they self-skip below 4 devices — run them under "
        "XLA_FLAGS=--xla_force_host_platform_device_count=4 (the "
        "multidev CI job does)")

