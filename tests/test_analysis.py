"""The one-compile invariant analyzer: passes clean on the repo, and
each deliberately broken fixture is caught with a finding that NAMES
the violated invariant (C00x / TH00x / PL00x / JX00x / RC001).

Layout mirrors the analyzer: contract checks, tracer-hygiene lint,
jaxpr-equivalence (incl. the full-family one-compile pin), the
recompile guard, and the CLI's exit-code contract.
"""
import importlib.util
from pathlib import Path

import jax
import jax.numpy as jnp
import pytest

from repro.analysis import __main__ as analysis_cli
from repro.analysis import contracts, jaxpr_equiv, lint, recompile

FIXTURES = Path(__file__).parent / "analysis_fixtures"


def _load_fixture(name):
    spec = importlib.util.spec_from_file_location(name, FIXTURES / f"{name}.py")
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


# ------------------------------------------------- repo must be clean


def test_repo_contracts_clean():
    assert contracts.run() == []


def test_repo_lint_clean():
    assert lint.run() == []


# --------------------------------------------------- contract checker


def test_bad_signature_stage_named():
    mod = _load_fixture("broken_stage")
    findings = contracts.check_stage_objects({"badsig": mod.BrokenStage()})
    text = "\n".join(findings)
    assert all(f.startswith("C001") for f in findings)
    assert "placeholder/missing 'name'" in text
    assert "'past_l2' must be declared as a bool" in text
    # the finding names the violated contract, not just the method
    assert "violates the stage contract" in text
    assert "('self', 'cfg', 'st', 'req', 'need')" in text


def test_foreign_info_write_named():
    findings = contracts.check_stage_info_writes(FIXTURES)
    assert len(findings) == 1
    assert findings[0].startswith("C008")
    assert "foreign result slot" in findings[0]
    assert "out[self.name].info" in findings[0]


def test_stats_fold_fixture_named():
    fields = ("n_used", "n_orphan", "n_overwrite", "n_shared", "bad_name")
    findings = contracts.check_stats_fold(fields, FIXTURES / "broken_fold.py")
    text = "\n".join(findings)
    assert "C005 Stats.bad_name: violates the n_*/sum_*/hist_* naming" in text
    assert "C005 Stats.n_orphan: not folded" in text
    assert "C005 Stats.n_overwrite: fold is not accumulative" in text
    assert "C006 Stats.n_shared" in text and "exactly one writer" in text
    # the clean field stays clean
    assert "Stats.n_used:" not in text


def test_orphan_stats_field_named():
    findings = contracts.check_stats_surfaced(
        ("n_used", "n_orphan"), [FIXTURES / "broken_metrics.py"])
    assert len(findings) == 1
    assert findings[0].startswith("C007 Stats.n_orphan: orphan")


# ------------------------------------------------ tracer-hygiene lint


def test_tracer_hygiene_fixture_all_rules_fire():
    findings = lint.check_files([FIXTURES / "broken_stage.py"])
    codes = sorted({f.split()[0] for f in findings})
    assert codes == ["TH001", "TH002", "TH003", "TH004"]
    text = "\n".join(findings)
    # int(tracer) and the Dyn-branch are each caught and explained
    assert "concretizes the tracer" in text
    assert "forks the trace per member" in text
    assert sum(f.startswith("TH001") for f in findings) == 2  # int + float


def test_pallas_resident_state_discipline_clean():
    assert lint.check_pallas() == []


# ----------------------------------------------------- jaxpr pass


def test_canonicalize_is_alpha_invariant():
    a = jax.make_jaxpr(lambda x: jnp.sin(x) + x)(jnp.zeros(4))
    b = jax.make_jaxpr(lambda y: jnp.sin(y) + y)(jnp.zeros(4))
    la, lb = jaxpr_equiv.canonicalize(a), jaxpr_equiv.canonicalize(b)
    assert la == lb
    assert jaxpr_equiv.diff_canonical("a", la, "b", lb) is None


def test_jaxpr_divergence_names_primitive():
    a = jaxpr_equiv.canonicalize(
        jax.make_jaxpr(lambda x: x + 1.0)(jnp.zeros(4)))
    b = jaxpr_equiv.canonicalize(
        jax.make_jaxpr(lambda x: x * 2.0)(jnp.zeros(4)))
    msg = jaxpr_equiv.diff_canonical("member_a", b, "member_b", a)
    assert msg is not None
    assert "'mul' vs 'add'" in msg  # the diverging primitive, by name


def test_python_gate_splits_family_like_jx001():
    # the exact failure mode JX001 exists for: a Python branch on a
    # config value produces structurally different jaxprs per member
    def step(gate):
        return lambda x: (x + 1.0) if gate else x

    on = jaxpr_equiv.canonicalize(jax.make_jaxpr(step(True))(jnp.zeros(4)))
    off = jaxpr_equiv.canonicalize(jax.make_jaxpr(step(False))(jnp.zeros(4)))
    assert jaxpr_equiv.diff_canonical("on", on, "off", off) is not None


@pytest.mark.slow
def test_all_ladder_families_one_compile():
    """The acceptance pin: native 28-member + virt 5-member families
    are provably one-compile (alpha-equivalent canonical jaxprs), and
    so is each 4-member multicore family (per-core private TLBs over
    the shared contended tier, incl. the DRAM-cache variant)."""
    reports, findings = jaxpr_equiv.check_all()
    assert findings == []
    by = {r.family: r for r in reports}
    assert by["radix"].n_members == 28
    assert by["np"].n_members == 5
    for c in (1, 2, 4):
        assert by[f"radix_{c}c"].n_members == 4, c
    assert all(r.equivalent for r in reports)
    assert all(r.n_eqns > 0 for r in reports)


def test_family_metadata_matches_registry():
    meta = jaxpr_equiv.family_metadata()
    assert meta["radix"]["n_members"] == 28
    assert meta["np"]["n_members"] == 5
    assert meta["radix_2c"]["n_members"] == 4


# ------------------------------------------------- recompile guard


def test_count_compiles_names_jit_cache_misses():
    @jax.jit
    def fixture_fn(x):
        return x * 3 + 1

    with recompile.count_compiles() as log:
        fixture_fn(jnp.zeros(8)).block_until_ready()
        fixture_fn(jnp.ones(8)).block_until_ready()  # cache hit
    assert log.count("fixture_fn") == 1


def test_recompile_guard_two_member_ladder():
    findings = recompile.check_ladder_dispatch(
        members=("np", "victima_virt"), workloads=("rnd", "bc"), n=256)
    assert findings == []


def test_run_ladder_records_one_compile(tmp_path, monkeypatch):
    from repro.sim import runner

    monkeypatch.setattr(runner, "CACHE_DIR", str(tmp_path))
    runner.run_ladder("np", members=("np", "victima_virt"),
                      workloads=("rnd", "bc"), n=128, backend="scan")
    rec = runner.LADDER_PERF[-1]
    assert rec["n_members"] == 2
    assert rec["dispatch_compiles"] <= 1  # warm persistent cache still logs
    assert rec["one_compile"] is True


# ----------------------------------------------------------- CLI


def test_cli_exits_zero_on_clean_repo(capsys):
    # contracts + lint only: the jaxpr pass has its own (slow) pin above
    rc = analysis_cli.main(["--pass", "contracts,lint", "-q"])
    assert rc == 0
    assert capsys.readouterr().out == ""


def test_cli_exits_nonzero_on_broken_fixture(capsys, monkeypatch):
    monkeypatch.setattr(lint, "DEFAULT_FILES",
                        (FIXTURES / "broken_stage.py",))
    rc = analysis_cli.main(["--pass", "lint", "-q"])
    out = capsys.readouterr().out
    assert rc == 1
    assert "TH001" in out and "concretizes the tracer" in out


def test_cli_rejects_unknown_pass():
    with pytest.raises(SystemExit):
        analysis_cli.main(["--pass", "nonsense"])


def test_cli_list_passes(capsys):
    assert analysis_cli.main(["--list"]) == 0
    out = capsys.readouterr().out
    for p in ("contracts", "lint", "jaxpr", "recompile"):
        assert p in out
