"""Per-architecture smoke tests: reduced config, one forward + train step
on CPU, output shapes + no NaNs (assignment deliverable f)."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, get_config, get_smoke_config
from repro.models.model import build, dummy_batch
from repro.optim import adamw
from repro.train.train_step import TrainConfig, init_state, make_train_step


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_and_decode(arch):
    cfg = get_smoke_config(arch)
    m = build(cfg)
    params = m.init(jax.random.PRNGKey(0))
    batch = dummy_batch(cfg, 2, 32)
    logits = m.forward(params, batch, remat=False)
    assert logits.shape == (2, 32, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))
    cache = m.init_cache(2, 32)
    lg, cache2 = m.decode_step(params, cache, batch["tokens"][:, :1],
                               jnp.zeros(2, jnp.int32))
    assert lg.shape[0] == 2 and lg.shape[-1] == cfg.padded_vocab
    assert bool(jnp.all(jnp.isfinite(lg)))
    assert jax.tree.structure(cache) == jax.tree.structure(cache2)


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step(arch):
    cfg = get_smoke_config(arch)
    m = build(cfg)
    params = m.init(jax.random.PRNGKey(0))
    state = init_state(params)
    tcfg = TrainConfig(opt=adamw.AdamWConfig(lr=1e-3, warmup_steps=1,
                                             total_steps=10))
    step = make_train_step(m, tcfg)
    batch = dummy_batch(cfg, 2, 32)
    state2, metrics = step(state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    # params actually moved
    moved = jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                           - b.astype(jnp.float32)))),
        state.params, state2.params)
    assert max(jax.tree.leaves(moved)) > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_param_count(arch):
    """Full configs import cleanly and report sane 6ND parameters."""
    cfg = get_config(arch)
    n = cfg.n_params()
    assert n > 1e8, (arch, n)  # every assigned arch is ≥ 0.1B params
    assert cfg.n_active_params() <= n
