"""System-registry coverage + golden-snapshot pipeline equivalence.

- every registered system constructs and simulates a ~2k-access trace
  without NaNs (systems sharing a tiny config + composition are
  simulated once — identical config => identical simulation);
- the stage pipeline reproduces the pre-refactor monolithic MMU's Stats
  bit-for-bit on a fixed seed (tests/golden/mmu_stats.json);
- a batched (vmapped) ladder run is bit-identical to per-system runs —
  for the L2-TLB geometry Dyn fields, the L2-*cache* geometry view
  (Fig. 25 family), the per-lane victima gate, and the virtualized
  2-D-walk pair;
- ladders are DISCOVERED from DYN_FIELDS-compatibility of registry
  entries (no hand-maintained member lists).
"""
import dataclasses
import json
import os

import jax.numpy as jnp
import numpy as np
import pytest

from golden_trace import (GOLDEN_CFG, GOLDEN_SYSTEMS, golden_trace,
                          stats_to_jsonable)
from repro.core.mmu import simulate, simulate_systems
from repro.core.stages import (Dyn, STAGES, WALK_STAGES, default_stages,
                               make_state)
from repro.sim import systems

GOLDEN_PATH = os.path.join(os.path.dirname(__file__), "golden",
                           "mmu_stats.json")

# shrink every structure so each distinct composition compiles in seconds
_TINY = dict(
    l2tlb_sets=4, l2tlb_ways=4,
    l1d4_sets=2, l1d4_ways=2, l1d2_sets=2, l1d2_ways=2,
    l2_sets=64, l2_ways=8, l3_sets=64, l3_ways=8,
    n_pages4=1 << 12, n_pages2=1 << 8, n_pagesh=1 << 8, n_feat=1 << 10,
)


def _tiny_config(name):
    cfg = dataclasses.replace(systems.config(name), **_TINY)
    if cfg.l3tlb_sets > 0:
        cfg = dataclasses.replace(cfg, l3tlb_sets=16, l3tlb_ways=4)
    if cfg.pom:
        cfg = dataclasses.replace(cfg, pom_sets=16, pom_ways=4)
    return cfg


def test_registry_compositions_are_canonical():
    assert len(systems.REGISTRY) >= 29
    for name, sys_ in systems.REGISTRY.items():
        assert sys_.stages == default_stages(sys_.config()), name
        assert sys_.stages[-1] in WALK_STAGES, name
        assert all(s in STAGES for s in sys_.stages), name


def test_ladders_are_shape_compatible():
    for ladder, members in systems.LADDERS.items():
        assert len(members) >= 2, ladder
        base = systems.ladder_base_config(ladder)
        dyns = systems.ladder_dyn(members)
        assert np.asarray(dyns.l2tlb_set_mask).shape == (len(members),)
        # base allocation covers every member's live geometry
        for m in members:
            c = systems.config(m)
            assert c.l2tlb_sets <= base.l2tlb_sets, m
            assert c.l2tlb_ways <= base.l2tlb_ways, m
            assert c.l2_sets <= base.l2_sets, m
            assert c.l2_ways <= base.l2_ways, m
            # a member may only lack stages the ladder can dyn-gate off
            extra = set(default_stages(base)) - set(systems.get(m).stages)
            assert extra <= set(systems.DYN_GATED_STAGES), (ladder, m)


def test_ladders_are_derived_from_registry():
    """LADDERS is discovered from DYN_FIELDS-compatibility, not a
    hand-maintained list: registering a new size variant must join it to
    its family's ladder automatically."""
    fake = dict(systems.REGISTRY)
    sys_ = systems.System(
        name="radix_l2_16m", stages=("l1_tlb", "l2_tlb", "ptw"),
        overrides={"l2_sets": 16384})
    fake["radix_l2_16m"] = sys_
    ladders = systems.discover_ladders(fake)
    containing = [m for m in ladders.values() if "radix_l2_16m" in m]
    assert len(containing) == 1
    assert "radix" in containing[0] and "victima" in containing[0]
    # and the real LADDERS matches a fresh discovery over the registry
    assert systems.LADDERS == systems.discover_ladders()


def test_fig25_family_shares_one_ladder():
    """The whole Fig. 25 L2-cache-size family — victima AND radix at
    1/2/4/8 MB — must batch into ONE compiled vmapped call."""
    fam = {"victima", "radix"} | {
        f"{p}_l2_{s}" for p in ("victima", "radix")
        for s in ("1m", "4m", "8m")}
    containing = [m for m in systems.LADDERS.values() if fam <= set(m)]
    assert len(containing) == 1, systems.LADDERS


def test_every_system_constructs():
    for name in systems.names():
        st = make_state(_tiny_config(name))
        assert int(st.now) == 0, name


@pytest.fixture(scope="module")
def tiny_trace():
    return {k: jnp.asarray(v) for k, v in golden_trace(n=2000).items()}


def test_every_system_simulates_without_nans(tiny_trace):
    by_cfg = {}
    for name in systems.names():
        key = (_tiny_config(name), systems.get(name).stages)
        by_cfg.setdefault(key, []).append(name)
    for (cfg, stage_names), group in by_cfg.items():
        stats, extras = simulate(cfg, tiny_trace, stage_names=stage_names)
        for field, v in zip(stats._fields, stats):
            arr = np.asarray(v)
            assert np.all(np.isfinite(arr)), (group, field)
        assert int(stats.n_access) == 2000, group
        assert int(stats.n_demand_ptw) > 0, group
        assert float(stats.sum_trans_cyc) > 0, group


def test_pipeline_matches_golden_snapshot():
    """The refactored stage pipeline must reproduce the pre-refactor
    monolithic make_step Stats bit-for-bit (fixed seed)."""
    with open(GOLDEN_PATH) as f:
        snap = json.load(f)
    tr = {k: jnp.asarray(v) for k, v in golden_trace().items()}
    for name, overrides in GOLDEN_SYSTEMS.items():
        cfg = dataclasses.replace(GOLDEN_CFG, **overrides)
        stats, _ = simulate(cfg, tr)
        got = stats_to_jsonable(stats)
        for field, want in snap[name].items():
            assert got[field] == want, (name, field, got[field], want)


def test_batched_ladder_matches_single_runs(tiny_trace):
    """vmapped multi-system sweep == per-system static runs, bit-for-bit
    (covers set-masking, way-limiting, and dynamic latency)."""
    variants = [dict(l2tlb_sets=8, l2tlb_ways=4, l2tlb_lat=12),
                dict(l2tlb_sets=16, l2tlb_ways=4, l2tlb_lat=17),
                dict(l2tlb_sets=16, l2tlb_ways=8, l2tlb_lat=23)]
    base = dataclasses.replace(GOLDEN_CFG, l2tlb_sets=16, l2tlb_ways=8)
    dyns = Dyn(
        l2tlb_set_mask=jnp.asarray(
            [v["l2tlb_sets"] - 1 for v in variants], jnp.int32),
        l2tlb_ways=jnp.asarray(
            [v["l2tlb_ways"] for v in variants], jnp.int32),
        l2tlb_lat=jnp.asarray(
            [v["l2tlb_lat"] for v in variants], jnp.int32),
        l3tlb_lat=jnp.asarray([base.l3tlb_lat] * len(variants), jnp.int32),
        l2_set_mask=jnp.asarray([base.l2_sets - 1] * len(variants),
                                jnp.int32),
        l2_ways=jnp.asarray([base.l2_ways] * len(variants), jnp.int32),
        victima_en=jnp.asarray([base.victima] * len(variants), jnp.bool_),
    )
    traces = {k: jnp.stack([v, v], axis=1) for k, v in tiny_trace.items()}
    per, extras = simulate_systems(base, dyns, traces)
    for si, v in enumerate(variants):
        ref, _ = simulate(dataclasses.replace(GOLDEN_CFG, **v), tiny_trace)
        for field, a, b in zip(ref._fields, ref, per[si][0]):
            assert np.array_equal(np.asarray(a), np.asarray(b)), (si, field)
        # both workload lanes saw the same trace -> identical stats
        assert np.array_equal(np.asarray(per[si][0].n_demand_ptw),
                              np.asarray(per[si][1].n_demand_ptw))


def _ladder_equivalence(base_cfg, variants, tiny_trace):
    """Batched (vmapped Dyn) run == per-variant static runs, bit-for-bit."""
    cfgs = [dataclasses.replace(base_cfg, **v) for v in variants]
    dyns = Dyn(
        l2tlb_set_mask=jnp.asarray([c.l2tlb_sets - 1 for c in cfgs],
                                   jnp.int32),
        l2tlb_ways=jnp.asarray([c.l2tlb_ways for c in cfgs], jnp.int32),
        l2tlb_lat=jnp.asarray([c.l2tlb_lat for c in cfgs], jnp.int32),
        l3tlb_lat=jnp.asarray([c.l3tlb_lat for c in cfgs], jnp.int32),
        l2_set_mask=jnp.asarray([c.l2_sets - 1 for c in cfgs], jnp.int32),
        l2_ways=jnp.asarray([c.l2_ways for c in cfgs], jnp.int32),
        victima_en=jnp.asarray([c.victima for c in cfgs], jnp.bool_),
    )
    base = dataclasses.replace(
        base_cfg,
        l2_sets=max(c.l2_sets for c in cfgs),
        l2_ways=max(c.l2_ways for c in cfgs),
        victima=any(c.victima for c in cfgs),
    )
    traces = {k: jnp.stack([v], axis=1) for k, v in tiny_trace.items()}
    per, _ = simulate_systems(base, dyns, traces)
    for si, c in enumerate(cfgs):
        ref, _ = simulate(c, tiny_trace)
        for field, a, b in zip(ref._fields, ref, per[si][0]):
            assert np.array_equal(np.asarray(a), np.asarray(b)), \
                (variants[si], field)


def test_batched_dyn_l2_cache_matches_single_runs(tiny_trace):
    """The Fig. 25 machinery: vmapped L2-cache geometry views + the
    per-lane victima gate == per-system static runs, bit-for-bit.  This
    covers the dyn set mask / way limit on every L2 path (victima probe,
    PTW fills, data accesses) and a radix lane riding a victima ladder."""
    _ladder_equivalence(
        GOLDEN_CFG,
        [dict(l2_sets=16, l2_ways=4, victima=True),
         dict(l2_sets=64, l2_ways=8, victima=False),
         dict(l2_sets=32, l2_ways=8, victima=True)],
        tiny_trace)


def test_batched_dyn_virt_matches_single_runs(tiny_trace):
    """np and victima_virt lanes share one compiled 2-D-walk ladder: the
    nested-TLB-block machinery dyn-gates off bit-exactly."""
    vbase = dataclasses.replace(GOLDEN_CFG, virt=True, l3_sets=16)
    _ladder_equivalence(
        vbase,
        [dict(victima=False), dict(victima=True, l2_sets=16, l2_ways=4)],
        tiny_trace)
