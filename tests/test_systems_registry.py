"""System-registry coverage + golden-snapshot pipeline equivalence.

- every registered system constructs and simulates a ~2k-access trace
  without NaNs (systems sharing a tiny config + composition are
  simulated once — identical config => identical simulation);
- the stage pipeline reproduces the pre-refactor monolithic MMU's Stats
  bit-for-bit on a fixed seed (tests/golden/mmu_stats.json);
- a batched (vmapped) ladder run is bit-identical to per-system runs.
"""
import dataclasses
import json
import os

import jax.numpy as jnp
import numpy as np
import pytest

from golden_trace import (GOLDEN_CFG, GOLDEN_SYSTEMS, golden_trace,
                          stats_to_jsonable)
from repro.core.mmu import simulate, simulate_systems
from repro.core.stages import (Dyn, STAGES, WALK_STAGES, default_stages,
                               make_state)
from repro.sim import systems

GOLDEN_PATH = os.path.join(os.path.dirname(__file__), "golden",
                           "mmu_stats.json")

# shrink every structure so each distinct composition compiles in seconds
_TINY = dict(
    l2tlb_sets=4, l2tlb_ways=4,
    l1d4_sets=2, l1d4_ways=2, l1d2_sets=2, l1d2_ways=2,
    l2_sets=64, l2_ways=8, l3_sets=64, l3_ways=8,
    n_pages4=1 << 12, n_pages2=1 << 8, n_pagesh=1 << 8, n_feat=1 << 10,
)


def _tiny_config(name):
    cfg = dataclasses.replace(systems.config(name), **_TINY)
    if cfg.l3tlb_sets > 0:
        cfg = dataclasses.replace(cfg, l3tlb_sets=16, l3tlb_ways=4)
    if cfg.pom:
        cfg = dataclasses.replace(cfg, pom_sets=16, pom_ways=4)
    return cfg


def test_registry_compositions_are_canonical():
    assert len(systems.REGISTRY) >= 29
    for name, sys_ in systems.REGISTRY.items():
        assert sys_.stages == default_stages(sys_.config()), name
        assert sys_.stages[-1] in WALK_STAGES, name
        assert all(s in STAGES for s in sys_.stages), name


def test_ladders_are_shape_compatible():
    for ladder, members in systems.LADDERS.items():
        assert len(members) >= 3, ladder
        base = systems.ladder_base_config(ladder)
        dyns = systems.ladder_dyn(members)
        assert np.asarray(dyns.l2tlb_set_mask).shape == (len(members),)
        # base allocation covers every member's live geometry
        for m in members:
            c = systems.config(m)
            assert c.l2tlb_sets <= base.l2tlb_sets, m
            assert c.l2tlb_ways <= base.l2tlb_ways, m


def test_every_system_constructs():
    for name in systems.names():
        st = make_state(_tiny_config(name))
        assert int(st.now) == 0, name


@pytest.fixture(scope="module")
def tiny_trace():
    return {k: jnp.asarray(v) for k, v in golden_trace(n=2000).items()}


def test_every_system_simulates_without_nans(tiny_trace):
    by_cfg = {}
    for name in systems.names():
        key = (_tiny_config(name), systems.get(name).stages)
        by_cfg.setdefault(key, []).append(name)
    for (cfg, stage_names), group in by_cfg.items():
        stats, extras = simulate(cfg, tiny_trace, stage_names=stage_names)
        for field, v in zip(stats._fields, stats):
            arr = np.asarray(v)
            assert np.all(np.isfinite(arr)), (group, field)
        assert int(stats.n_access) == 2000, group
        assert int(stats.n_demand_ptw) > 0, group
        assert float(stats.sum_trans_cyc) > 0, group


def test_pipeline_matches_golden_snapshot():
    """The refactored stage pipeline must reproduce the pre-refactor
    monolithic make_step Stats bit-for-bit (fixed seed)."""
    with open(GOLDEN_PATH) as f:
        snap = json.load(f)
    tr = {k: jnp.asarray(v) for k, v in golden_trace().items()}
    for name, overrides in GOLDEN_SYSTEMS.items():
        cfg = dataclasses.replace(GOLDEN_CFG, **overrides)
        stats, _ = simulate(cfg, tr)
        got = stats_to_jsonable(stats)
        for field, want in snap[name].items():
            assert got[field] == want, (name, field, got[field], want)


def test_batched_ladder_matches_single_runs(tiny_trace):
    """vmapped multi-system sweep == per-system static runs, bit-for-bit
    (covers set-masking, way-limiting, and dynamic latency)."""
    variants = [dict(l2tlb_sets=8, l2tlb_ways=4, l2tlb_lat=12),
                dict(l2tlb_sets=16, l2tlb_ways=4, l2tlb_lat=17),
                dict(l2tlb_sets=16, l2tlb_ways=8, l2tlb_lat=23)]
    base = dataclasses.replace(GOLDEN_CFG, l2tlb_sets=16, l2tlb_ways=8)
    dyns = Dyn(
        l2tlb_set_mask=jnp.asarray(
            [v["l2tlb_sets"] - 1 for v in variants], jnp.int32),
        l2tlb_ways=jnp.asarray(
            [v["l2tlb_ways"] for v in variants], jnp.int32),
        l2tlb_lat=jnp.asarray(
            [v["l2tlb_lat"] for v in variants], jnp.int32),
        l3tlb_lat=jnp.asarray([base.l3tlb_lat] * len(variants), jnp.int32),
    )
    traces = {k: jnp.stack([v, v], axis=1) for k, v in tiny_trace.items()}
    per, extras = simulate_systems(base, dyns, traces)
    for si, v in enumerate(variants):
        ref, _ = simulate(dataclasses.replace(GOLDEN_CFG, **v), tiny_trace)
        for field, a, b in zip(ref._fields, ref, per[si][0]):
            assert np.array_equal(np.asarray(a), np.asarray(b)), (si, field)
        # both workload lanes saw the same trace -> identical stats
        assert np.array_equal(np.asarray(per[si][0].n_demand_ptw),
                              np.asarray(per[si][1].n_demand_ptw))
