"""System-registry coverage + golden-snapshot pipeline equivalence.

- every registered system constructs and simulates a ~2k-access trace
  without NaNs (systems sharing a tiny config + composition are
  simulated once — identical config => identical simulation);
- the stage pipeline reproduces the pre-refactor monolithic MMU's Stats
  bit-for-bit on a fixed seed (tests/golden/mmu_stats.json);
- a batched (vmapped) ladder run is bit-identical to per-system runs —
  for the L2-TLB geometry Dyn fields, the L2-*cache* geometry view
  (Fig. 25 family), the per-lane rev/victima/restseg/l3_tlb/pom gates,
  and the virtualized 2-D-walk family;
- ladders are DISCOVERED from DYN_FIELDS-compatibility of registry
  entries (no hand-maintained member lists), and the discovered
  families' membership is pinned (a registry entry silently falling out
  of a batched family is a regression).
"""
import dataclasses
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from golden_trace import (GOLDEN_CFG, GOLDEN_SYSTEMS, golden_trace,
                          stats_to_jsonable)
from repro.core.mmu import simulate, simulate_systems
from repro.core.stages import (STAGES, WALK_STAGES, default_stages, dyn_of,
                               make_state)
from repro.sim import systems

GOLDEN_PATH = os.path.join(os.path.dirname(__file__), "golden",
                           "mmu_stats.json")

# shrink every structure so each distinct composition compiles in seconds
_TINY = dict(
    l2tlb_sets=4, l2tlb_ways=4,
    l1d4_sets=2, l1d4_ways=2, l1d2_sets=2, l1d2_ways=2,
    l2_sets=64, l2_ways=8, l3_sets=64, l3_ways=8,
    n_pages4=1 << 12, n_pages2=1 << 8, n_pagesh=1 << 8, n_feat=1 << 10,
)


def _tiny_config(name):
    cfg = dataclasses.replace(systems.config(name), **_TINY)
    if cfg.l3tlb_sets > 0:
        cfg = dataclasses.replace(cfg, l3tlb_sets=16, l3tlb_ways=4)
    if cfg.pom:
        cfg = dataclasses.replace(cfg, pom_sets=16, pom_ways=4)
    if cfg.utopia:
        cfg = dataclasses.replace(cfg, restseg4_sets=16, restseg2_sets=8,
                                  restseg_ways=min(cfg.restseg_ways, 8))
    if cfg.revelator:
        cfg = dataclasses.replace(cfg, rev_sets=16, rev_ways=4,
                                  rev_sig_bits=10)
    if cfg.dram_cache_sets > 0:
        cfg = dataclasses.replace(cfg, dram_cache_sets=16,
                                  dram_cache_ways=4)
    return cfg


def test_registry_compositions_are_canonical():
    assert len(systems.REGISTRY) >= 37
    for name, sys_ in systems.REGISTRY.items():
        assert sys_.stages == default_stages(sys_.config()), name
        assert sys_.stages[-1] in WALK_STAGES, name
        assert all(s in STAGES for s in sys_.stages), name


def test_ladders_are_shape_compatible():
    for ladder, members in systems.LADDERS.items():
        assert len(members) >= 2, ladder
        base = systems.ladder_base_config(ladder)
        dyns = systems.ladder_dyn(members)
        assert np.asarray(dyns.l2tlb_set_mask).shape == (len(members),)
        # base allocation covers every member's live geometry
        for m in members:
            c = systems.config(m)
            assert c.l2tlb_sets <= base.l2tlb_sets, m
            assert c.l2tlb_ways <= base.l2tlb_ways, m
            assert c.l2_sets <= base.l2_sets, m
            assert c.l2_ways <= base.l2_ways, m
            # a member may only lack stages the ladder can dyn-gate off
            extra = set(default_stages(base)) - set(systems.get(m).stages)
            assert extra <= set(systems.DYN_GATED_STAGES), (ladder, m)


def test_ladders_are_derived_from_registry():
    """LADDERS is discovered from DYN_FIELDS-compatibility, not a
    hand-maintained list: registering a new size variant must join it to
    its family's ladder automatically."""
    fake = dict(systems.REGISTRY)
    sys_ = systems.System(
        name="radix_l2_16m", stages=("l1_tlb", "l2_tlb", "ptw"),
        overrides={"l2_sets": 16384})
    fake["radix_l2_16m"] = sys_
    ladders = systems.discover_ladders(fake)
    containing = [m for m in ladders.values() if "radix_l2_16m" in m]
    assert len(containing) == 1
    assert "radix" in containing[0] and "victima" in containing[0]
    # and the real LADDERS matches a fresh discovery over the registry
    assert systems.LADDERS == systems.discover_ladders()


def test_fig25_family_shares_one_ladder():
    """The whole Fig. 25 L2-cache-size family — victima AND radix at
    1/2/4/8 MB — must batch into ONE compiled vmapped call."""
    fam = {"victima", "radix"} | {
        f"{p}_l2_{s}" for p in ("victima", "radix")
        for s in ("1m", "4m", "8m")}
    containing = [m for m in systems.LADDERS.values() if fam <= set(m)]
    assert len(containing) == 1, systems.LADDERS


def test_every_system_constructs():
    for name in systems.names():
        st = make_state(_tiny_config(name))
        assert int(st.now) == 0, name


@pytest.fixture(scope="module")
def tiny_trace():
    return {k: jnp.asarray(v) for k, v in golden_trace(n=2000).items()}


def test_every_system_simulates_without_nans(tiny_trace):
    by_cfg = {}
    for name in systems.names():
        key = (_tiny_config(name), systems.get(name).stages)
        by_cfg.setdefault(key, []).append(name)
    for (cfg, stage_names), group in by_cfg.items():
        stats, extras = simulate(cfg, tiny_trace, stage_names=stage_names)
        for field, v in zip(stats._fields, stats):
            arr = np.asarray(v)
            assert np.all(np.isfinite(arr)), (group, field)
        assert int(stats.n_access) == 2000, group
        assert int(stats.n_demand_ptw) > 0, group
        assert float(stats.sum_trans_cyc) > 0, group


def test_pipeline_matches_golden_snapshot():
    """The refactored stage pipeline must reproduce the pre-refactor
    monolithic make_step Stats bit-for-bit (fixed seed)."""
    with open(GOLDEN_PATH) as f:
        snap = json.load(f)
    tr = {k: jnp.asarray(v) for k, v in golden_trace().items()}
    for name, overrides in GOLDEN_SYSTEMS.items():
        cfg = dataclasses.replace(GOLDEN_CFG, **overrides)
        stats, _ = simulate(cfg, tr)
        got = stats_to_jsonable(stats)
        for field, want in snap[name].items():
            assert got[field] == want, (name, field, got[field], want)


def _stack_dyns(cfgs):
    """Per-config Dyn scalars stacked into [S]-leaves (via dyn_of, so the
    field-to-config mapping lives in exactly one place)."""
    return jax.tree.map(lambda *xs: jnp.stack(xs), *[dyn_of(c) for c in cfgs])


def test_batched_ladder_matches_single_runs(tiny_trace):
    """vmapped multi-system sweep == per-system static runs, bit-for-bit
    (covers set-masking, way-limiting, and dynamic latency)."""
    variants = [dict(l2tlb_sets=8, l2tlb_ways=4, l2tlb_lat=12),
                dict(l2tlb_sets=16, l2tlb_ways=4, l2tlb_lat=17),
                dict(l2tlb_sets=16, l2tlb_ways=8, l2tlb_lat=23)]
    base = dataclasses.replace(GOLDEN_CFG, l2tlb_sets=16, l2tlb_ways=8)
    dyns = _stack_dyns(
        [dataclasses.replace(GOLDEN_CFG, **v) for v in variants])
    traces = {k: jnp.stack([v, v], axis=1) for k, v in tiny_trace.items()}
    per, extras = simulate_systems(base, dyns, traces)
    for si, v in enumerate(variants):
        ref, _ = simulate(dataclasses.replace(GOLDEN_CFG, **v), tiny_trace)
        for field, a, b in zip(ref._fields, ref, per[si][0]):
            assert np.array_equal(np.asarray(a), np.asarray(b)), (si, field)
        # both workload lanes saw the same trace -> identical stats
        assert np.array_equal(np.asarray(per[si][0].n_demand_ptw),
                              np.asarray(per[si][1].n_demand_ptw))


def _ladder_equivalence(base_cfg, variants, tiny_trace):
    """Batched (vmapped Dyn) run == per-variant static runs, bit-for-bit."""
    cfgs = [dataclasses.replace(base_cfg, **v) for v in variants]
    dyns = _stack_dyns(cfgs)
    base = systems.dyn_base_config(cfgs)
    traces = {k: jnp.stack([v], axis=1) for k, v in tiny_trace.items()}
    per, _ = simulate_systems(base, dyns, traces)
    for si, c in enumerate(cfgs):
        ref, _ = simulate(c, tiny_trace)
        for field, a, b in zip(ref._fields, ref, per[si][0]):
            assert np.array_equal(np.asarray(a), np.asarray(b)), \
                (variants[si], field)


def test_batched_dyn_l2_cache_matches_single_runs(tiny_trace):
    """The Fig. 25 machinery: vmapped L2-cache geometry views + the
    per-lane victima gate == per-system static runs, bit-for-bit.  This
    covers the dyn set mask / way limit on every L2 path (victima probe,
    PTW fills, data accesses) and a radix lane riding a victima ladder."""
    _ladder_equivalence(
        GOLDEN_CFG,
        [dict(l2_sets=16, l2_ways=4, victima=True),
         dict(l2_sets=64, l2_ways=8, victima=False),
         dict(l2_sets=32, l2_ways=8, victima=True)],
        tiny_trace)


_TINY_RS = dict(restseg4_sets=16, restseg2_sets=8, restseg_ways=4)
# tiny signature table: 16 sets x 4 ways with a 10-bit lossy signature,
# so the 4096-page golden trace actually exercises alias mispredicts
_TINY_REV = dict(rev_sets=16, rev_ways=4, rev_sig_bits=10)


def test_batched_dyn_virt_matches_single_runs(tiny_trace):
    """np, victima_virt, pom_virt, utopia_virt and revelator_virt lanes
    share one compiled 2-D-walk ladder: the nested-TLB-block, POM,
    RestSeg and speculative-verification machinery dyn-gates off
    bit-exactly."""
    vbase = dataclasses.replace(GOLDEN_CFG, virt=True, l3_sets=16,
                                pom_sets=16, pom_ways=4, **_TINY_RS,
                                **_TINY_REV)
    _ladder_equivalence(
        vbase,
        [dict(victima=False), dict(victima=True, l2_sets=16, l2_ways=4),
         dict(utopia=True), dict(pom=True), dict(revelator=True)],
        tiny_trace)


def test_batched_dyn_utopia_matches_single_runs(tiny_trace):
    """Utopia lanes riding the batched family: the RestSeg probe/
    migration machinery dyn-gates off bit-exactly on non-utopia lanes,
    and the restseg_ways view matches smaller static RestSegs."""
    base_cfg = dataclasses.replace(GOLDEN_CFG, **_TINY_RS)
    _ladder_equivalence(
        base_cfg,
        [dict(utopia=True, restseg_ways=4),
         dict(),  # plain radix lane: utopia machinery masked off
         dict(utopia=True, restseg_ways=8),
         dict(utopia=True, victima=True, restseg_ways=8)],
        tiny_trace)


def test_batched_dyn_revelator_matches_single_runs(tiny_trace):
    """Revelator lanes riding the batched native family: the signature
    probe, verification walk and enrollment machinery dyn-gate off
    bit-exactly on non-revelator lanes, and a revelator lane matches
    its static per-system run bit-for-bit."""
    base_cfg = dataclasses.replace(GOLDEN_CFG, **_TINY_REV)
    _ladder_equivalence(
        base_cfg,
        [dict(revelator=True),
         dict(),  # plain radix lane: revelator machinery masked off
         dict(revelator=True, victima=True)],
        tiny_trace)


def test_batched_dyn_l3_pom_gates_match_single_runs(tiny_trace):
    """The l3_tlb and pom stages dyn-gate per lane: L3/POM systems and a
    plain radix lane share one compiled step, bit-exactly."""
    base_cfg = dataclasses.replace(GOLDEN_CFG, l3tlb_ways=4,
                                   pom_sets=16, pom_ways=4)
    _ladder_equivalence(
        base_cfg,
        [dict(), dict(l3tlb_sets=16), dict(pom=True),
         dict(l3tlb_sets=16, l3tlb_lat=24)],
        tiny_trace)


def test_batched_all_gates_combined_matches_single_runs(tiny_trace):
    """The production shape: the discovered native family's base
    composition carries ALL five gated stages (rev + victima + restseg +
    l3_tlb + pom) at once, so one lane of each flavour must still be
    bit-identical to its static run under the combined fill_order
    (l2_tlb -> victima -> restseg -> rev -> pom -> l3_tlb -> l1_tlb)."""
    base_cfg = dataclasses.replace(GOLDEN_CFG, l3tlb_ways=4,
                                   pom_sets=16, pom_ways=4, **_TINY_RS,
                                   **_TINY_REV)
    _ladder_equivalence(
        base_cfg,
        [dict(),  # plain radix: every gated stage masked off
         dict(utopia=True, victima=True),
         dict(revelator=True),
         dict(pom=True),
         dict(l3tlb_sets=16)],
        tiny_trace)


def test_ladder_discovery_regression():
    """Pin the discovered families: a registry entry silently falling out
    of its batched ladder (e.g. a new override knocking it off the
    DYN_FIELDS-compatible set) is a sweep-throughput regression, not a
    crash — so assert count and membership explicitly."""
    ladders = systems.LADDERS
    assert set(ladders) == {"radix", "np",
                            "radix_1c", "radix_2c", "radix_4c"}, ladders
    native = set(ladders["radix"])
    assert native >= {
        "radix", "victima", "pom", "utopia", "utopia_victima",
        "utopia_rs8", "utopia_rs32", "revelator", "revelator_victima",
        "l3tlb_64k_15", "l3tlb_64k_24", "l3tlb_64k_39",
        "l2tlb_3k", "l2tlb_128k", "l2tlb_64k_real",
        "victima_l2_8m", "radix_l2_8m",
    }, native
    assert len(native) == 28, sorted(native)
    assert set(ladders["np"]) == {"np", "victima_virt", "pom_virt",
                                  "utopia_virt", "revelator_virt"}
    # each multicore family batches its whole scheme set — including the
    # die-stacked-DRAM-cache variant — into one compile per core count
    for c in (1, 2, 4):
        assert set(ladders[f"radix_{c}c"]) == {
            f"radix_{c}c", f"victima_{c}c", f"pom_{c}c",
            f"victima_dramc_{c}c"}, ladders[f"radix_{c}c"]
    # every registered system is either a ladder member or one of the
    # known singletons (configs differing beyond DYN_FIELDS)
    covered = {m for mem in ladders.values() for m in mem}
    singles = set(systems.REGISTRY) - covered
    assert singles == {"victima_agnostic", "victima_noptwcp",
                       "radix_collect", "isp"}, singles
