"""Paged-attention Pallas kernel vs oracle (incl. ragged context lens)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


@pytest.mark.parametrize("B,H,K,hd,page,nb,P", [
    (2, 4, 2, 64, 64, 4, 16),
    (1, 8, 1, 32, 32, 8, 16),   # MQA
    (4, 4, 4, 16, 16, 2, 32),   # MHA
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_matches_ref(B, H, K, hd, page, nb, P, dtype):
    k1, k2, k3, k4 = jax.random.split(jax.random.PRNGKey(1), 4)
    q = jax.random.normal(k1, (B, H, hd), jnp.float32).astype(dtype)
    kp = jax.random.normal(k2, (P, page, K, hd), jnp.float32).astype(dtype)
    vp = jax.random.normal(k3, (P, page, K, hd), jnp.float32).astype(dtype)
    tables = jax.random.permutation(k4, P)[:B * nb].reshape(B, nb)
    lens = jnp.asarray(
        np.random.default_rng(0).integers(1, nb * page, size=B), jnp.int32)
    o = ops.paged_attention(q, kp, vp, tables, lens)
    r = ref.paged_attention_reference(q, kp, vp, tables, lens)
    tol = 3e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(r, np.float32),
                               atol=tol, rtol=tol)


def test_paged_permutation_invariance():
    """Physical page placement must not affect the result — the whole
    point of the translation layer."""
    B, H, K, hd, page, nb, P = 2, 4, 2, 32, 32, 4, 32
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(k1, (B, H, hd), jnp.float32)
    kp = jax.random.normal(k2, (P, page, K, hd), jnp.float32)
    vp = jax.random.normal(k3, (P, page, K, hd), jnp.float32)
    tables = jnp.arange(B * nb).reshape(B, nb)
    lens = jnp.full((B,), nb * page, jnp.int32)
    o1 = ops.paged_attention(q, kp, vp, tables, lens)
    # permute physical pages + remap tables accordingly
    perm = jax.random.permutation(k1, P)
    inv = jnp.argsort(perm)
    o2 = ops.paged_attention(q, kp[inv], vp[inv], perm[tables], lens)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               atol=1e-5, rtol=1e-5)
