"""An accum_stats that drops, overwrites, and double-writes fields."""


def Stats(**kw):  # stub so the fixture parses/lints standalone
    return kw


def accum_stats(s0, out, walk_res):
    l1 = out["l1_tlb"].info["hit"]
    shared = out["l1_tlb"].info["hit"] + out["l2_tlb"].info["hit"]
    return Stats(
        n_used=s0.n_used + l1,                   # clean
        n_overwrite=out["l2_tlb"].info["miss"],  # C005: never reads s0
        n_shared=s0.n_shared + shared,           # C006: two stage writers
        # n_orphan deliberately missing          # C005: not folded
    )
