"""A stage that violates every stage-contract and tracer-hygiene rule.

Kept import-clean (numpy only) so tests can instantiate ``BrokenStage``
for the introspection checks without touching the simulator.
"""
import numpy as np


class BrokenStage:
    name = "?"          # C001: placeholder name
    past_l2 = "yes"     # C001: past_l2 must be a bool

    def lookup(self, cfg, state, req):  # C001: wrong parameter list
        return int(state)  # TH001: int() concretizes a tracer

    def fill(self, cfg, st, req, out):
        out["l2_tlb"].info["stolen"] = 0  # C008: foreign result slot
        if st.valid:  # TH002: Python branch on a traced value
            st = st.bump
        total = np.sum(st.counts)  # TH003: host numpy on a tracer
        for v in st:  # TH004: Python loop over a traced pytree
            total = total + v
        return float(req.vpn)  # TH001 again


def gated_probe(cfg, st, dyn):
    # TH002: branching on a Dyn gate splits the one-compile family —
    # exactly the bug the jaxpr pass names as a JX001 divergence
    if dyn.rev_en:
        return st
    return st
