"""Deliberately broken inputs for the `repro.analysis` passes.

Each file here violates a specific set of checked invariants so the
tests can assert the analyzer catches — and *names* — every one:

- ``broken_stage.py`` — stage-contract violations (C001 signature /
  name / past_l2, C008 foreign info write) and tracer-hygiene
  violations (TH001 int()/float() on traced values, TH002 branching on
  a traced/Dyn value, TH003 np.* on a tracer, TH004 Python loop over a
  traced pytree).
- ``broken_fold.py`` — Stats fold violations (C005 orphan field /
  non-accumulative fold / naming convention, C006 multi-writer).
- ``broken_metrics.py`` — a metrics module that surfaces only some
  fields, leaving an orphan for C007.

These modules are never executed by the simulator; the contract and
lint passes consume them as AST/objects only.
"""
