"""A metrics module that reads n_used but never n_orphan (C007)."""


def used_rate(stats):
    return float(stats.n_used)
