"""Dynamic-geometry views + path-independent sim cache.

Property tests (deterministic random op sequences — no hypothesis
dependency) pin the core ladder invariant: a structure allocated at its
ladder-maximum shape, operated through a masked view, is BIT-IDENTICAL
to a statically allocated smaller structure:

- assoc.lookup_dyn / insert_lru_dyn   (L2 TLB views, PR 1)
- caches.L2Geom through l2_lookup / l2_insert / l2_retag_to_tlb /
  l2_touch and the access_data / access_pte composite paths (this PR)

Plus the Utopia RestSeg invariants (occupancy never exceeds the live
way count; a RestSeg hit resolves with ZERO walker cycles) and the
runner satellites: run() and run_batch() must write byte-identical
cache entries for the same key, and _key must digest non-JSON override
values (Lat, numpy/jnp scalars) without aliasing.
"""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import assoc, caches
from repro.core.caches import BT_DATA, BT_TLB2, BT_TLB4, L2Geom, Lat

SEED = 20260730


# ------------------------------------------------------------- assoc views


def test_assoc_masked_view_equals_small_static():
    rng = np.random.default_rng(SEED)
    SETS, WAYS = 8, 4
    big = assoc.make(4 * SETS, 2 * WAYS)
    small = assoc.make(SETS, WAYS)
    mask = jnp.int32(SETS - 1)
    ways = jnp.int32(WAYS)
    for t in range(300):
        key = jnp.int32(rng.integers(0, 1 << 20))
        now = jnp.int32(t)
        if rng.random() < 0.5:
            hb, wb, sb = assoc.lookup_dyn(big, key, mask, ways)
            hs, ws, ss = assoc.lookup(small, key)
            assert bool(hb) == bool(hs)
            if bool(hs):
                assert int(wb) == int(ws) and int(sb) == int(ss)
                big = assoc.touch_lru(big, sb, wb, now)
                small = assoc.touch_lru(small, ss, ws, now)
        else:
            en = bool(rng.random() < 0.9)
            big, ev_t_b, ev_v_b = assoc.insert_lru_dyn(
                big, key, now, mask, ways, en)
            small, ev_t_s, ev_v_s = assoc.insert_lru(small, key, now, en)
            assert bool(ev_v_b) == bool(ev_v_s)
            if bool(ev_v_s):
                assert int(ev_t_b) == int(ev_t_s)
    assert np.array_equal(np.asarray(big.tags)[:SETS, :WAYS],
                          np.asarray(small.tags))
    assert np.array_equal(np.asarray(big.valid)[:SETS, :WAYS],
                          np.asarray(small.valid))
    assert np.array_equal(np.asarray(big.meta)[:SETS, :WAYS],
                          np.asarray(small.meta))
    # the view never leaks outside its live geometry
    live = np.zeros_like(np.asarray(big.valid), bool)
    live[:SETS, :WAYS] = True
    assert not np.asarray(big.valid)[~live].any()


def _assert_l2_view_equal(big, small, sets, ways):
    for field in ("tags", "valid", "rrpv", "btype", "reuse"):
        a = np.asarray(getattr(big, field))[:sets, :ways]
        b = np.asarray(getattr(small, field))
        assert np.array_equal(a, b), field
    for field in ("hist_reuse_data", "hist_reuse_tlb",
                  "n_tlb4", "n_tlb2", "n_ntlb"):
        assert np.array_equal(np.asarray(getattr(big, field)),
                              np.asarray(getattr(small, field))), field
    live = np.zeros((big.tags.shape[0], big.tags.shape[1]), bool)
    live[:sets, :ways] = True
    assert not np.asarray(big.valid)[~live].any()


@pytest.mark.parametrize("tlb_aware", [True, False])
def test_l2_cache_masked_view_equals_small_static(tlb_aware):
    """Random l2_insert/l2_lookup/l2_touch/l2_retag_to_tlb sequences:
    the L2Geom view of a 4x-oversized L2 == a statically small L2."""
    rng = np.random.default_rng(SEED + tlb_aware)
    SETS, WAYS = 8, 4
    big = caches.make_l2(4 * SETS, 4 * WAYS)
    small = caches.make_l2(SETS, WAYS)
    geom = L2Geom(set_mask=jnp.int32(SETS - 1), n_ways=jnp.int32(WAYS))
    bts = [BT_DATA, BT_TLB4, BT_TLB2]
    for t in range(400):
        key = jnp.int32(rng.integers(0, 1 << 16))
        bt = bts[rng.integers(0, len(bts))]
        pressure = jnp.bool_(rng.random() < 0.5)
        op = rng.random()
        if op < 0.25:
            hb, wb, sb = caches.l2_lookup(big, key, bt, geom)
            hs, ws, ss = caches.l2_lookup(small, key, bt)
            assert bool(hb) == bool(hs), t
            if bool(hs):
                assert int(wb) == int(ws) and int(sb) == int(ss)
                big = caches.l2_touch(big, sb, wb, pressure, tlb_aware,
                                      True)
                small = caches.l2_touch(small, ss, ws, pressure,
                                        tlb_aware, True)
        elif op < 0.65:
            en = bool(rng.random() < 0.9)
            big = caches.l2_insert(big, key, bt, pressure, tlb_aware, en,
                                   geom)
            small = caches.l2_insert(small, key, bt, pressure, tlb_aware,
                                     en)
        else:
            tlb_bt = BT_TLB2 if bt == BT_TLB2 else BT_TLB4
            big = caches.l2_retag_to_tlb(big, key, tlb_bt, pressure,
                                         tlb_aware, True, geom)
            small = caches.l2_retag_to_tlb(small, key, tlb_bt, pressure,
                                           tlb_aware, True)
    _assert_l2_view_equal(big, small, SETS, WAYS)


def test_hier_access_paths_masked_view_equals_small_static():
    """access_data + access_pte composites (incl. prefetch + background
    traffic + L3 interaction) under an L2Geom view == small static L2."""
    rng = np.random.default_rng(SEED)
    SETS, WAYS = 16, 4
    lat = Lat()
    big = caches.make_hier(l1_sets=4, l1_ways=2, l2_sets=4 * SETS,
                           l2_ways=2 * WAYS, l3_sets=16, l3_ways=4)
    small = caches.make_hier(l1_sets=4, l1_ways=2, l2_sets=SETS,
                             l2_ways=WAYS, l3_sets=16, l3_ways=4)
    geom = L2Geom(set_mask=jnp.int32(SETS - 1), n_ways=jnp.int32(WAYS))
    for t in range(200):
        line = jnp.int32(rng.integers(0, 1 << 14))
        now = jnp.int32(t + 1)
        pressure = jnp.bool_(rng.random() < 0.5)
        if rng.random() < 0.7:
            big, cb = caches.access_data(big, line, now, pressure, True,
                                         lat, geom)
            small, cs = caches.access_data(small, line, now, pressure,
                                           True, lat)
        else:
            big, cb, db = caches.access_pte(big, line, pressure, True,
                                            lat, True, bt=BT_TLB4,
                                            geom=geom)
            small, cs, ds = caches.access_pte(small, line, pressure, True,
                                              lat, True, bt=BT_TLB4)
            assert bool(db) == bool(ds), t
        assert int(cb) == int(cs), t
    _assert_l2_view_equal(big.l2, small.l2, SETS, WAYS)
    assert np.array_equal(np.asarray(big.l3.tags), np.asarray(small.l3.tags))
    assert np.array_equal(np.asarray(big.l1d.tags),
                          np.asarray(small.l1d.tags))


# ------------------------------------------------------- utopia restseg


def test_restseg_masked_view_equals_small_static():
    """The RestSeg migrate/probe path (insert_lru_dyn + lookup_dyn under
    a way limit) over an oversized allocation == a statically small
    RestSeg, and occupancy never exceeds the live way count."""
    rng = np.random.default_rng(SEED)
    SETS, WAYS = 8, 4
    big = assoc.make(SETS, 4 * WAYS)   # ladder-maximum way allocation
    small = assoc.make(SETS, WAYS)
    mask = jnp.int32(SETS - 1)
    ways = jnp.int32(WAYS)
    for t in range(300):
        vpn = jnp.int32(rng.integers(0, 1 << 16))
        now = jnp.int32(t)
        if rng.random() < 0.5:  # probe (+ LRU touch on hit)
            hb, wb, sb = assoc.lookup_dyn(big, vpn, mask, ways)
            hs, ws, ss = assoc.lookup(small, vpn)
            assert bool(hb) == bool(hs), t
            if bool(hs):
                assert int(wb) == int(ws) and int(sb) == int(ss)
                big = assoc.touch_lru(big, sb, wb, now)
                small = assoc.touch_lru(small, ss, ws, now)
        else:  # migration; a conflict demotes the LRU resident
            mig = bool(rng.random() < 0.8)
            big, _, conf_b = assoc.insert_lru_dyn(big, vpn, now, mask,
                                                  ways, mig)
            small, _, conf_s = assoc.insert_lru(small, vpn, now, mig)
            assert bool(conf_b) == bool(conf_s), t
        occupancy = np.asarray(big.valid).sum(axis=1)
        assert occupancy.max() <= WAYS, t
    assert np.array_equal(np.asarray(big.tags)[:, :WAYS],
                          np.asarray(small.tags))
    assert not np.asarray(big.valid)[:, WAYS:].any()


def _simulate_final_state(cfg, trace, dyn=None):
    from repro.core.mmu import make_state, make_step

    step = make_step(cfg, dyn=dyn)

    @jax.jit
    def run(tr):
        st, _ = jax.lax.scan(step, make_state(cfg), tr)
        return st

    return run(trace)


def test_restseg_migration_invariants():
    """End-to-end utopia run: every RestSeg hit is walk-free (hits +
    demand walks exactly cover the L2-TLB misses), migrations only
    follow walks, conflicts only follow migrations — and under a dyn
    way limit nothing is ever resident outside the live ways."""
    from golden_trace import GOLDEN_CFG, golden_trace
    from repro.core.mmu import simulate
    from repro.core.stages import dyn_of

    cfg = dataclasses.replace(GOLDEN_CFG, utopia=True, restseg4_sets=16,
                              restseg2_sets=8, restseg_ways=4)
    trace = {k: jnp.asarray(v) for k, v in golden_trace(n=2000).items()}
    stats, _ = simulate(cfg, trace)
    hits = int(stats.n_restseg_hit)
    assert hits > 0
    # RestSeg hit => zero walk cycles: walks + hits partition the misses
    assert hits + int(stats.n_demand_ptw) == int(stats.n_l2tlb_miss)
    assert hits + int(stats.n_restseg_miss) == int(stats.n_l2tlb_miss)
    assert int(stats.n_restseg_mig) <= int(stats.n_demand_ptw)
    assert int(stats.n_restseg_conflict) <= int(stats.n_restseg_mig)
    assert int(np.asarray(stats.hist_restseg).sum()) \
        == hits + int(stats.n_restseg_miss)

    # dyn way-limited run: occupancy stays inside the live view
    ways_alloc = dataclasses.replace(cfg, restseg_ways=8)
    st = _simulate_final_state(ways_alloc, trace, dyn=dyn_of(cfg))
    for rs in (st.restseg4, st.restseg2):
        valid = np.asarray(rs.valid)
        assert valid.sum(axis=1).max() <= cfg.restseg_ways
        assert not valid[:, cfg.restseg_ways:].any()
    assert np.asarray(st.restseg4.valid).any()  # migrations landed


# ------------------------------------------------------- revelator


def test_revelator_speculation_invariants():
    """End-to-end revelator run: speculative hits + mispredicts + demand
    walks exactly cover the L2-TLB misses, mispredicts DO occur under a
    lossy signature (the alias model), every speculative resolution pays
    an overlapped verification walk, and enrollment only follows walks."""
    import dataclasses as dc

    from golden_trace import GOLDEN_CFG, golden_trace
    from repro.core.mmu import simulate

    cfg = dc.replace(GOLDEN_CFG, revelator=True, rev_sets=16, rev_ways=4,
                     rev_sig_bits=10)
    trace = {k: jnp.asarray(v) for k, v in golden_trace(n=2000).items()}
    stats, _ = simulate(cfg, trace)
    hits = int(stats.n_rev_hit)
    mis = int(stats.n_rev_mispred)
    assert hits > 0 and mis > 0
    # speculation resolves without the demand walker: partition holds
    assert hits + mis + int(stats.n_demand_ptw) == int(stats.n_l2tlb_miss)
    # every speculative resolution was verified by a real (overlapped)
    # walk; verification is never free
    assert int(np.asarray(stats.hist_rev_verify).sum()) == hits + mis
    assert float(stats.sum_rev_verify_cyc) > 0
    # enrollment is PTW-CP-gated after demand walks only
    assert int(stats.n_rev_enroll) <= int(stats.n_demand_ptw)
    assert int(stats.n_rev_enroll) > 0


# --------------------------------------------------- path-independent cache


_TINY = dict(
    l2tlb_sets=4, l2tlb_ways=4,
    l1d4_sets=2, l1d4_ways=2, l1d2_sets=2, l1d2_ways=2,
    l2_sets=64, l2_ways=8, l3_sets=64, l3_ways=8,
    n_pages4=1 << 12, n_pages2=1 << 8, n_pagesh=1 << 8, n_feat=1 << 10,
)


def test_run_and_run_batch_write_identical_cache_entries(tmp_path,
                                                         monkeypatch):
    """Fresh-cache run() and run_batch() must produce byte-identical
    entries for the same (system, workload, n, seed, overrides) —
    cached Stats must not depend on which code path filled them."""
    from repro.sim import runner

    n, seed, w = 1500, 3, "bc"
    dir_a, dir_b = str(tmp_path / "a"), str(tmp_path / "b")

    monkeypatch.setattr(runner, "CACHE_DIR", dir_a)
    res_run = runner.run("radix", w, n=n, seed=seed, overrides=_TINY)
    monkeypatch.setattr(runner, "CACHE_DIR", dir_b)
    res_batch = runner.run_batch("radix", workloads=[w], n=n, seed=seed,
                                 overrides=_TINY)[w]

    key = runner._key("radix", w, n, seed, _TINY) + ".pkl"
    with open(os.path.join(dir_a, key), "rb") as f:
        blob_a = f.read()
    with open(os.path.join(dir_b, key), "rb") as f:
        blob_b = f.read()
    assert blob_a == blob_b
    for field, a, b in zip(res_run[0]._fields, res_run[0], res_batch[0]):
        assert np.array_equal(np.asarray(a), np.asarray(b)), field


def test_key_canonicalizes_non_json_overrides():
    from repro.sim import runner

    # NamedTuple / dataclass values must not crash
    k_lat = runner._key("radix", "bc", 10, 0, {"lat": Lat(l2=20)})
    assert k_lat != runner._key("radix", "bc", 10, 0,
                                {"lat": (4, 20, 35, 160)})
    # numpy / jnp scalars hash like the equivalent python numbers
    # (they produce the same replace()d config, so they must share a key)
    assert runner._key("radix", "bc", 10, 0, {"l2_sets": np.int32(64)}) \
        == runner._key("radix", "bc", 10, 0, {"l2_sets": 64})
    assert runner._key("radix", "bc", 10, 0, {"l2_sets": jnp.int32(64)}) \
        == runner._key("radix", "bc", 10, 0, {"l2_sets": 64})
    # distinct values stay distinct
    assert runner._key("radix", "bc", 10, 0, {"l2_sets": 64}) \
        != runner._key("radix", "bc", 10, 0, {"l2_sets": 128})
    # still stable for plain-JSON overrides (legacy keys unchanged)
    assert runner._key("radix", "bc", 10, 0, {"victima": True}) \
        == runner._key("radix", "bc", 10, 0, {"victima": True})


def test_ptw_reduction_zero_baseline_is_zero():
    """A baseline with no demand walks has nothing to reduce: the old
    ``1 - new/max(base, 1)`` returned a large NEGATIVE number instead
    of 0.0 whenever the comparison system did walk."""
    import types

    from repro.core import metrics

    none = types.SimpleNamespace(n_demand_ptw=0)
    some = types.SimpleNamespace(n_demand_ptw=500)
    assert metrics.ptw_reduction(none, some) == 0.0
    assert metrics.ptw_reduction(some, none) == 1.0
    assert metrics.ptw_reduction(some, some) == 0.0
    assert metrics.reduction(100, 25) == 0.75


def test_sweep_rejects_unknown_systems_before_simulating():
    from repro.sim import sweep

    with pytest.raises(SystemExit, match="unknown system"):
        sweep.main(["radix", "definitely_not_a_system"])


_NO_OPTS = {"mesh": None, "devices": None, "backend": None, "time_shards": 1,
            "obs_trace": None, "cores": None, "mix": []}


def test_sweep_parse_args_accepts_both_tag_forms():
    from repro.sim import sweep

    assert sweep.parse_args(["--tags", "native,ablation"]) \
        == ([], ["native", "ablation"], _NO_OPTS)
    assert sweep.parse_args(["--tags=utopia"]) == ([], ["utopia"], _NO_OPTS)
    assert sweep.parse_args(["radix", "--tags", "virt", "pom"]) \
        == (["radix", "pom"], ["virt"], _NO_OPTS)


def test_sweep_parse_args_mesh_and_devices():
    from repro.sim import sweep

    assert sweep.parse_args(["--mesh", "2x2", "--devices", "4"]) \
        == ([], [], {**_NO_OPTS, "mesh": (2, 2), "devices": 4})
    assert sweep.parse_args(["--mesh=4x1", "radix"]) \
        == (["radix"], [], {**_NO_OPTS, "mesh": (4, 1)})
    with pytest.raises(SystemExit, match="SYSxWL"):
        sweep.parse_args(["--mesh", "4"])
    with pytest.raises(SystemExit, match="positive integer"):
        sweep.parse_args(["--devices", "zero"])
    with pytest.raises(SystemExit, match=r"needs a SYSxWL\[xCORE\] value"):
        sweep.parse_args(["--mesh", "--tags"])


def test_sweep_parse_args_rejects_flag_like_tag_values():
    """``--tags --foo`` used to silently swallow the next option as a
    tag list; flag-like values must error out instead."""
    from repro.sim import sweep

    with pytest.raises(SystemExit, match="needs a comma-separated value"):
        sweep.parse_args(["--tags", "--foo"])
    with pytest.raises(SystemExit, match="needs a comma-separated value"):
        sweep.parse_args(["--tags=-foo"])
    with pytest.raises(SystemExit, match="needs a comma-separated value"):
        sweep.parse_args(["--tags"])  # missing value entirely


def test_run_ladder_reuses_cached_member_cells(tmp_path, monkeypatch):
    """A workload with SOME members cached used to re-simulate and
    REWRITE every member's entry; cached cells must be returned as-is
    (neither recomputed nor rewritten — mtime/bytes unchanged) and only
    the missing cells stored."""
    from repro.core.stages import zero_stats
    from repro.sim import runner

    monkeypatch.setattr(runner, "CACHE_DIR", str(tmp_path))
    members, wls, n, seed = ("radix", "victima"), ["bc", "bfs"], 64, 7

    # pre-seed ONE cell with sentinel content the stub cannot produce
    sentinel = ({"marker": "seeded"}, {"extras": 1}, None)
    seeded = runner._path("radix", "bc", n, seed, None)
    runner._store(seeded, sentinel)
    stat0 = os.stat(seeded)
    with open(seeded, "rb") as f:
        bytes0 = f.read()

    calls = []

    def fake_make_systems_runner(cfg, plan, stage_names=None, **kwargs):
        def fake_run(dyns, traces):
            import jax
            S = jax.tree.leaves(dyns)[0].shape[0]
            W = jax.tree.leaves(traces)[0].shape[1]
            calls.append((S, W))
            per = [[zero_stats() for _ in range(W)] for _ in range(S)]
            extras = [[{"stub": True} for _ in range(W)] for _ in range(S)]
            return per, extras
        return fake_run

    monkeypatch.setattr(runner, "make_systems_runner",
                        fake_make_systems_runner)
    out = runner.run_ladder("radix", workloads=wls, n=n, seed=seed,
                            members=members)

    # the seeded cell came back from the cache, not the stub...
    assert out["radix"]["bc"] == sentinel
    # ...its bytes and mtime are untouched...
    stat1 = os.stat(seeded)
    with open(seeded, "rb") as f:
        assert f.read() == bytes0
    assert stat1.st_mtime_ns == stat0.st_mtime_ns
    # ...and the three genuinely missing cells were simulated + stored in
    # ONE dispatch at the auto-tuned chunk width (derived from the FULL
    # workload list, so a partially-cached rerun reuses the same shape)
    assert calls == [(len(members), runner.auto_chunk(len(wls)))]
    for s, w in [("victima", "bc"), ("radix", "bfs"), ("victima", "bfs")]:
        assert out[s][w][1] == {"stub": True}, (s, w)
        assert os.path.exists(runner._path(s, w, n, seed, None)), (s, w)


def test_trace_gen_reports_total_page_count():
    from repro.sim import trace_gen

    gen = trace_gen.generate("bc", n=1000, seed=0)
    assert "n_pages4" not in gen  # renamed: it was the TOTAL page count
    assert gen["n_pages"] > 0
    assert int(np.max(gen["trace"]["vpn"])) < gen["n_pages"]
