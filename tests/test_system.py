"""End-to-end behaviour tests for the full system."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.data.pipeline import DataConfig, Pipeline
from repro.models.model import build
from repro.optim import adamw
from repro.train.train_step import TrainConfig, init_state, make_train_step


def test_train_then_decode_roundtrip(tmp_path):
    """Train a tiny LM for 20 steps, checkpoint, restore, decode greedily —
    the full substrate path a deployment exercises."""
    from repro.ckpt.checkpoint import CheckpointManager
    cfg = get_smoke_config("yi-6b")
    m = build(cfg)
    params = m.init(jax.random.PRNGKey(0))
    state = init_state(params)
    tcfg = TrainConfig(opt=adamw.AdamWConfig(lr=3e-3, warmup_steps=2,
                                             total_steps=20))
    step = jax.jit(make_train_step(m, tcfg))
    data = Pipeline(DataConfig(vocab_size=cfg.vocab_size, batch=4,
                               seq_len=32, seed=3))
    losses = []
    for s in range(20):
        state, metrics = step(state, {"tokens": jnp.asarray(data.batch_at(s))})
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0]

    mgr = CheckpointManager(str(tmp_path), async_write=False)
    mgr.save(20, state)
    restored, rstep = mgr.restore(state)
    assert rstep == 20

    # greedy decode 8 tokens from the restored params
    cache = m.init_cache(1, 16)
    tok = jnp.asarray([[1]], jnp.int32)
    outs = []
    for pos in range(8):
        logits, cache = m.decode_step(restored.params, cache, tok,
                                      jnp.asarray([pos], jnp.int32))
        tok = jnp.argmax(logits[:, -1:, :cfg.vocab_size], -1).astype(jnp.int32)
        outs.append(int(tok[0, 0]))
    assert all(0 <= t < cfg.vocab_size for t in outs)


def test_decode_matches_prefill_logits():
    """Teacher-forced decode must reproduce the forward logits (the
    KV-cache path is numerically consistent with the parallel path)."""
    cfg = get_smoke_config("granite-3-2b")
    m = build(cfg)
    params = m.init(jax.random.PRNGKey(1))
    toks = jax.random.randint(jax.random.PRNGKey(2), (2, 12), 0,
                              cfg.vocab_size, dtype=jnp.int32)
    full = m.forward(params, {"tokens": toks}, remat=False)

    cache = m.init_cache(2, 16)
    step_logits = []
    for t in range(12):
        lg, cache = m.decode_step(params, cache, toks[:, t:t + 1],
                                  jnp.full((2,), t, jnp.int32))
        step_logits.append(lg[:, 0])
    dec = jnp.stack(step_logits, axis=1)
    np.testing.assert_allclose(np.asarray(dec, np.float32),
                               np.asarray(full, np.float32),
                               atol=2e-2, rtol=2e-2)


def test_victima_sim_end_to_end_tiny():
    """Simulator → metrics → timing chain stays coherent on a real
    workload generator output (miniature)."""
    from repro.core import metrics, timing
    from repro.sim.runner import run
    st, ex, spec = run("radix", "bfs", n=4000, cache=False)
    assert int(st.n_access) == 4000
    assert 0 < metrics.l2tlb_mpki(st, spec.ipa) < 400
    assert 0 < timing.translation_fraction(st, spec.ipa) < 0.9
    assert ex["l2_access"] > 0
