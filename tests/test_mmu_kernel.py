"""Pallas access-scan kernel + time-axis sharding: backend bit-identity.

- ``mmu_step.pick_block`` / ``parallel.pick_t_shards`` /
  ``runner.auto_chunk`` unit tests: exact-divisor tiling (padding the
  time axis would simulate phantom accesses), env overrides, rejection
  of empty/absurd inputs;
- ``mmu.resolve_backend`` validation (explicit arg and
  ``REPRO_SIM_BACKEND``) and the sweep CLI's upfront ``--backend`` /
  ``--time-shards`` rejection;
- ``blocked_scan`` == ``lax.scan`` on a toy carry for several block
  sizes, and ``time_shard_scan`` == a serial fold with the hand-off
  resolving in <= t rounds;
- the pallas backend (interpret mode on CPU) produces Stats
  bit-identical to the scan backend for EVERY member of the native and
  virt ladder families (tiny-shrunk configs, one batched call per
  backend), for ``simulate``/``simulate_batch``, and through a
  time-sharded (>= 2 block) run;
- ``run_ladder(backend="pallas")`` writes cache entries byte-identical
  to the scan fill and records backend/block/chunk_auto in LADDER_PERF;
- [multidev] time-sharded simulate on the forced 4-device mesh (blocks
  laid out on the ("t",) axis) still matches the serial scan
  bit-for-bit.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from golden_trace import GOLDEN_CFG, golden_trace
from repro.core import mmu
from repro.kernels import mmu_step
from repro.sim import parallel, systems
from test_parallel import _tiny_registry
from test_systems_registry import _stack_dyns, _tiny_config

multidev = pytest.mark.multidev


# ------------------------------------------------------------- unit: tiling


def test_pick_block_targets_the_grid_sweet_spot():
    # no target: the divisor whose grid length is nearest TARGET_GRID
    assert mmu_step.pick_block(2000) == 250      # grid 8
    assert mmu_step.pick_block(6000) == 750      # grid 8
    assert mmu_step.pick_block(512) == 64        # grid 8
    assert mmu_step.pick_block(149) == 149       # prime: one whole block
    assert mmu_step.pick_block(8) == 1           # grid 8 even when tiny


def test_pick_block_explicit_target_snaps_to_divisor():
    assert mmu_step.pick_block(2000, 100) == 100
    assert mmu_step.pick_block(2000, 99) == 100  # nearest divisor
    assert mmu_step.pick_block(2000, 3) == 4     # tie 2/4 prefers larger
    with pytest.raises(ValueError, match="empty trace"):
        mmu_step.pick_block(0)
    with pytest.raises(ValueError, match=">= 1"):
        mmu_step.pick_block(100, 0)


def test_pick_block_env_override(monkeypatch):
    monkeypatch.setenv("REPRO_PALLAS_BLOCK", "500")
    assert mmu_step.pick_block(2000) == 500
    monkeypatch.setenv("REPRO_PALLAS_BLOCK", "")
    assert mmu_step.pick_block(2000) == 250


def test_pick_t_shards_rounds_down_to_divisor():
    assert parallel.pick_t_shards(600, 4) == 4
    assert parallel.pick_t_shards(600, 7) == 6   # 7 does not divide
    assert parallel.pick_t_shards(149, 4) == 1   # prime: no sharding
    assert parallel.pick_t_shards(600, 1) == 1
    with pytest.raises(ValueError, match="empty trace"):
        parallel.pick_t_shards(0, 2)
    with pytest.raises(ValueError, match=">= 1"):
        parallel.pick_t_shards(600, 0)


def test_auto_chunk_minimizes_dispatches_then_padding():
    from repro.sim import runner

    # 3 workloads: one dispatch, zero padding (the old fixed chunk=4
    # simulated a 4th, discarded lane)
    assert runner.auto_chunk(3) == 3
    assert runner.auto_chunk(1) == 1
    assert runner.auto_chunk(8) == 8
    assert runner.auto_chunk(12) == 6   # 2 dispatches, 0 padding (not 8/4pad)
    assert runner.auto_chunk(20) == 7   # 3 dispatches, 1 padded lane
    assert runner.auto_chunk(11, cap=4) == 4
    with pytest.raises(ValueError, match="no workloads"):
        runner.auto_chunk(0)


# --------------------------------------------------- unit: backend selection


def test_resolve_backend_validates(monkeypatch):
    monkeypatch.delenv("REPRO_SIM_BACKEND", raising=False)
    assert mmu.resolve_backend() == "scan"
    assert mmu.resolve_backend("pallas") == "pallas"
    with pytest.raises(ValueError, match="unknown simulation backend"):
        mmu.resolve_backend("fast")
    monkeypatch.setenv("REPRO_SIM_BACKEND", "pallas")
    assert mmu.resolve_backend() == "pallas"
    assert mmu.resolve_backend("scan") == "scan"  # explicit arg wins
    monkeypatch.setenv("REPRO_SIM_BACKEND", "bogus")
    with pytest.raises(ValueError, match="REPRO_SIM_BACKEND"):
        mmu.resolve_backend()


def test_sweep_cli_rejects_bad_backend_and_time_shards():
    """A typo'd --backend must die at parse time, BEFORE any ladder
    compile (mirroring the --tags fix)."""
    from repro.sim.sweep import parse_args

    assert parse_args(["--backend", "pallas"])[2]["backend"] == "pallas"
    assert parse_args(["--time-shards=4"])[2]["time_shards"] == 4
    with pytest.raises(SystemExit, match="unknown simulation backend"):
        parse_args(["--backend", "fast"])
    with pytest.raises(SystemExit, match="backend name"):
        parse_args(["--backend"])
    with pytest.raises(SystemExit, match="positive integer"):
        parse_args(["--time-shards", "0"])
    with pytest.raises(SystemExit, match="1x1"):
        parse_args(["--time-shards", "2", "--mesh", "2x2"])
    # a 1x1 mesh is the one forced factorization time sharding allows
    opts = parse_args(["--time-shards", "2", "--mesh", "1x1"])[2]
    assert opts["time_shards"] == 2 and opts["mesh"] == (1, 1)


# ----------------------------------------------- unit: blocked_scan mechanics


def _toy_step(st, acc, consts=None):
    """Order-dependent toy carry (gather/scatter like the real probes)."""
    tab, tot = st
    idx = acc % tab.shape[0]
    mul = 3 if consts is None else consts["mul"]
    tab = tab.at[idx].set(tab[idx] * mul + acc)
    return (tab, tot + tab[idx]), ()


def test_blocked_scan_matches_lax_scan_across_block_sizes():
    tr = jnp.arange(96, dtype=jnp.int32) * 7 + 1
    st0 = (jnp.zeros((5,), jnp.int32), jnp.int32(0))
    ref, _ = jax.lax.scan(_toy_step, st0, tr)
    for blk in (None, 96, 48, 12, 1):
        got = mmu_step.blocked_scan(_toy_step, st0, tr, block=blk)
        for a, b in zip(jax.tree.leaves(ref), jax.tree.leaves(got)):
            assert np.array_equal(np.asarray(a), np.asarray(b)), blk


def test_blocked_scan_delivers_consts_and_hoists_closures():
    """Per-call constants ride as kernel inputs, and constants baked
    into the step's CLOSURE (the stage composition does this) are
    hoisted automatically instead of tripping pallas's captured-consts
    error."""
    tr = jnp.arange(48, dtype=jnp.int32)
    st0 = (jnp.zeros((5,), jnp.int32), jnp.int32(0))
    consts = {"mul": jnp.int32(5)}
    ref, _ = jax.lax.scan(lambda s, a: _toy_step(s, a, consts), st0, tr)
    got = mmu_step.blocked_scan(_toy_step, st0, tr, consts=consts, block=12)
    for a, b in zip(jax.tree.leaves(ref), jax.tree.leaves(got)):
        assert np.array_equal(np.asarray(a), np.asarray(b))

    bias = jnp.int32(11)  # captured closure constant, not an input

    def closed_step(st, acc):
        return _toy_step(st, acc + bias)

    ref2, _ = jax.lax.scan(closed_step, st0, tr)
    got2 = mmu_step.blocked_scan(closed_step, st0, tr, block=16)
    for a, b in zip(jax.tree.leaves(ref2), jax.tree.leaves(got2)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_time_shard_scan_resolves_carry_handoff():
    tr = jnp.arange(60, dtype=jnp.int32)
    st0 = (jnp.zeros((4,), jnp.int32), jnp.int32(0))

    def block_fn(st, tr_blk):
        st, _ = jax.lax.scan(_toy_step, st, tr_blk)
        return st

    ref = block_fn(st0, tr)
    for t, batch in [(4, "vmap"), (3, "map"), (1, "vmap"), (7, "vmap")]:
        got, info = parallel.time_shard_scan(block_fn, st0, tr, t,
                                             batch=batch)
        assert info["t_shards"] == parallel.pick_t_shards(60, t)
        assert 1 <= info["rounds"] <= info["t_shards"]
        for a, b in zip(jax.tree.leaves(ref), jax.tree.leaves(got)):
            assert np.array_equal(np.asarray(a), np.asarray(b)), (t, batch)
    with pytest.raises(ValueError, match="batch mode"):
        parallel.time_shard_scan(block_fn, st0, tr, 2, batch="pmap")


# ------------------------------------------ backend bit-identity (families)


def _assert_same_stats(ref, got, ctx):
    for field, a, b in zip(ref._fields, ref, got):
        assert np.array_equal(np.asarray(a), np.asarray(b)), (ctx, field)


def _family_ladder(name_frag):
    """The discovered ladder containing ``name_frag``, tiny-shrunk."""
    members = next(m for m in systems.LADDERS.values() if name_frag in m)
    cfgs = [_tiny_config(s) for s in members]
    return members, systems.dyn_base_config(cfgs), _stack_dyns(cfgs)


@pytest.fixture(scope="module")
def short_traces():
    tr = {k: jnp.asarray(v) for k, v in golden_trace(n=256).items()}
    return tr, {k: jnp.stack([v], axis=1) for k, v in tr.items()}


@pytest.mark.parametrize("anchor", ["radix", "np"])
def test_pallas_backend_matches_scan_on_ladder_family(anchor, short_traces,
                                                      monkeypatch):
    """EVERY member of the native (28-system) and virt (5-system)
    families: one batched scan-backend call vs one batched
    pallas(interpret) call, Stats bit-for-bit.  This drives the full
    stage composition — TLB/assoc/RestSeg/Revelator state and all dyn
    gates — through the resident-state kernel."""
    monkeypatch.delenv("REPRO_SIM_BACKEND", raising=False)
    _, traces = short_traces
    members, base, dyns = _family_ladder(anchor)
    per_s, ex_s = mmu.simulate_systems(base, dyns, traces)
    per_p, ex_p = mmu.simulate_systems(base, dyns, traces,
                                       backend="pallas")
    for si, name in enumerate(members):
        _assert_same_stats(per_s[si][0], per_p[si][0], name)
        assert ex_s[si][0]["l2_access"] == ex_p[si][0]["l2_access"], name
        assert ex_s[si][0]["l2_miss"] == ex_p[si][0]["l2_miss"], name


def test_pallas_backend_matches_scan_simulate_and_batch(short_traces):
    tr, _ = short_traces
    cfg = dataclasses.replace(GOLDEN_CFG, victima=True)
    ref, ex_ref = mmu.simulate(cfg, tr)
    got, ex_got = mmu.simulate(cfg, tr, backend="pallas")
    _assert_same_stats(ref, got, "simulate")
    assert ex_ref["l2_access"] == ex_got["l2_access"]

    traces = {k: jnp.stack([v, v], axis=1) for k, v in tr.items()}
    per_s, _ = mmu.simulate_batch(cfg, traces)
    per_p, _ = mmu.simulate_batch(cfg, traces, backend="pallas")
    for w in range(2):
        _assert_same_stats(per_s[w], per_p[w], ("batch", w))


def test_time_sharded_simulate_matches_serial(short_traces):
    """>= 2 speculative trace blocks, hand-off resolved: bit-identical
    to the serial scan on both backends (256 accesses / 4 shards)."""
    tr, _ = short_traces
    ref, _ = mmu.simulate(GOLDEN_CFG, tr)
    got4, _ = mmu.simulate(GOLDEN_CFG, tr, time_shards=4)
    _assert_same_stats(ref, got4, "t4-scan")
    got2p, _ = mmu.simulate(GOLDEN_CFG, tr, backend="pallas",
                            time_shards=2)
    _assert_same_stats(ref, got2p, "t2-pallas")


def test_time_sharded_systems_requires_1x1_plan(short_traces):
    _, traces = short_traces
    cfgs = [GOLDEN_CFG, dataclasses.replace(GOLDEN_CFG, victima=True)]
    base, dyns = systems.dyn_base_config(cfgs), _stack_dyns(cfgs)
    per_ref, _ = mmu.simulate_systems(base, dyns, traces)
    per_t, _ = mmu.simulate_systems(base, dyns, traces, time_shards=4)
    for si in range(2):
        _assert_same_stats(per_ref[si][0], per_t[si][0], si)
    plan = parallel.plan_mesh(2, 1, n_devices=1, force=(2, 1))
    with pytest.raises(ValueError, match="1x1"):
        mmu.make_systems_runner(base, plan, time_shards=2)


# --------------------------------------------- runner/perf-record plumbing


def test_run_ladder_pallas_backend_cache_byte_identical(tmp_path,
                                                        monkeypatch):
    """run_ladder(backend='pallas') must write cache entries
    BYTE-identical to the scan fill (the backend is deliberately absent
    from cache keys) and stamp backend/block/chunk_auto into
    LADDER_PERF."""
    from repro.sim import runner

    monkeypatch.setattr(systems, "REGISTRY", _tiny_registry())
    monkeypatch.delenv("REPRO_SIM_BACKEND", raising=False)
    members = ("t_radix", "t_victima")
    wls, n, seed = ["bc", "xs"], 256, 3

    def fill(cache_dir, backend):
        monkeypatch.setattr(runner, "CACHE_DIR", str(cache_dir))
        out = runner.run_ladder("tiny", workloads=wls, n=n, seed=seed,
                                members=members, backend=backend)
        assert set(out) == set(members)
        return out

    out_s = fill(tmp_path / "scan", None)
    out_p = fill(tmp_path / "pallas", "pallas")

    perf = runner.LADDER_PERF[-2:]
    assert [p["backend"] for p in perf] == ["scan", "pallas"]
    assert perf[0]["block"] is None
    assert perf[1]["block"] == mmu_step.pick_block(n)
    assert all(p["chunk_auto"] for p in perf)
    assert all(p["chunk"] == 2 for p in perf)  # auto_chunk(2 workloads)
    assert all(p["t_shards"] == 1 for p in perf)

    for s in members:
        for w in wls:
            key = runner._key(s, w, n, seed, None) + ".pkl"
            blob_s = (tmp_path / "scan" / key).read_bytes()
            blob_p = (tmp_path / "pallas" / key).read_bytes()
            assert blob_s == blob_p, (s, w)
            _assert_same_stats(out_s[s][w][0], out_p[s][w][0], (s, w))


def test_backend_speedup_line_pairs_fills():
    import benchmarks.paper as paper

    fills = [
        {"ladder": "native", "sim_n": 2000, "n_workloads": 3,
         "backend": "scan", "compile_plus_sim_wall_s": 60.0},
        {"ladder": "native", "sim_n": 2000, "n_workloads": 3,
         "backend": "pallas", "block": 250,
         "compile_plus_sim_wall_s": 30.0},
        {"ladder": "virt", "sim_n": 2000, "n_workloads": 3,
         "backend": "scan", "compile_plus_sim_wall_s": 9.0},
    ]
    line = paper.backend_speedup_line(fills)
    assert "native" in line and "2.00x" in line and "block 250" in line
    # one backend only -> nothing to print
    assert paper.backend_speedup_line(fills[:1]) is None
    assert paper.backend_speedup_line([]) is None


# --------------------------------------------------- multidev time sharding


@multidev
def test_time_sharded_simulate_multidev_matches_serial(short_traces):
    """Time-axis sharding on the forced 4-device mesh: 4 speculative
    blocks laid out on the ("t",) axis resolve to the exact serial
    carry."""
    if jax.local_device_count() < 4:
        pytest.skip("needs XLA_FLAGS=--xla_force_host_platform_device_count"
                    "=4 (see the multidev CI job)")
    tr, traces = short_traces
    ref, _ = mmu.simulate(GOLDEN_CFG, tr)
    got, _ = mmu.simulate(GOLDEN_CFG, tr, time_shards=4)
    _assert_same_stats(ref, got, "simulate-t4")

    cfgs = [GOLDEN_CFG, dataclasses.replace(GOLDEN_CFG, victima=True)]
    base, dyns = systems.dyn_base_config(cfgs), _stack_dyns(cfgs)
    per_ref, _ = mmu.simulate_systems(base, dyns, traces)
    per_t, _ = mmu.simulate_systems(base, dyns, traces, time_shards=4)
    for si in range(2):
        _assert_same_stats(per_ref[si][0], per_t[si][0], ("sys-t4", si))
