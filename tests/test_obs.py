"""Tests for the ``repro.obs`` tracing/metrics layer.

Covers the tentpole contracts: span nesting (implicit thread-local +
explicit cross-thread parents, the ``run_ladder`` producer-pool shape),
JSONL file <-> in-memory bit-exactness, the schema-6 round trip
(``LADDER_PERF`` records reproduce offline from the raw trace), tracer
overhead bounds, the metrics registry's tracer-safety under jit, the
serve-path counters, the report/diff CLI, and the OB001 analyzer pass.
"""
from __future__ import annotations

import json
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.obs as obs
from repro.obs import names, report
from repro.obs.registry import Registry, host_value
from repro.obs.tracer import Tracer


@pytest.fixture
def tr(tmp_path):
    """A fresh PROCESS-GLOBAL tracer on a temp file (restored after)."""
    t = obs.configure(str(tmp_path / "trace.jsonl"))
    yield t
    obs.configure()  # later tests get the default path back


# ------------------------------------------------------------ tracer


def test_span_nesting_implicit_parent(tr):
    with tr.span("outer") as outer:
        with tr.span("inner") as inner:
            assert tr.current() is inner
        assert tr.current() is outer
    assert tr.current() is None
    recs = {e["name"]: e for e in tr.events}
    assert recs["inner"]["parent"] == recs["outer"]["id"]
    assert recs["outer"]["parent"] is None
    # children close (and emit) before parents
    assert tr.events.index(recs["inner"]) < tr.events.index(recs["outer"])


def test_span_explicit_parent_crosses_threads(tr):
    """The run_ladder shape: worker-thread spans attach to the fill."""
    with tr.span("fill") as fill:
        def work(i):
            # implicit stack is thread-local: without parent= this span
            # would be a root, not a fill child
            with tr.span("gen", parent=fill, wl=i):
                pass
        threads = [threading.Thread(target=work, args=(i,))
                   for i in range(8)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
    gens = [e for e in tr.events if e["name"] == "gen"]
    assert len(gens) == 8
    assert all(e["parent"] == fill.id for e in gens)
    assert sorted(e["attrs"]["wl"] for e in gens) == list(range(8))
    # ids are unique under concurrency
    ids = [e["id"] for e in tr.events]
    assert len(ids) == len(set(ids))


def test_worker_root_span_does_not_leak_across_threads(tr):
    seen = {}

    def work():
        seen["current"] = tr.current()
    with tr.span("outer"):
        t = threading.Thread(target=work)
        t.start()
        t.join()
    assert seen["current"] is None  # implicit parent never crosses threads


def test_attrs_sanitized_at_emission(tr):
    with tr.span("s", np_scalar=np.int64(7), jnp_scalar=jnp.float32(1.5),
                 arr=np.arange(3), nested={"k": (1, 2)}):
        pass
    a = tr.events[-1]["attrs"]
    assert a["np_scalar"] == 7 and isinstance(a["np_scalar"], int)
    assert a["jnp_scalar"] == 1.5 and isinstance(a["jnp_scalar"], float)
    assert a["arr"] == [0, 1, 2]
    assert a["nested"] == {"k": [1, 2]}
    # the whole record JSON round-trips exactly
    assert json.loads(json.dumps(tr.events[-1])) == tr.events[-1]


def test_jsonl_file_matches_memory_bit_exact(tr):
    with tr.span("fill", x=1.234567891234):
        tr.event("ev", v=np.float64(0.1))
        tr.count("ctr", 3)
    tr.flush()
    assert report.read_trace(tr.path) == tr.events


def test_span_error_flag(tr):
    with pytest.raises(RuntimeError):
        with tr.span("boom"):
            raise RuntimeError("x")
    assert tr.events[-1]["error"] is True


def test_tracer_lazy_file_creation(tmp_path):
    t = Tracer(str(tmp_path / "sub" / "t.jsonl"))
    assert not (tmp_path / "sub").exists()  # import/construct: no I/O
    t.event("e")
    assert (tmp_path / "sub" / "t.jsonl").exists()
    meta = report.read_trace(t.path)  # meta line is stripped
    assert len(meta) == 1 and meta[0]["name"] == "e"
    t.close()


# ---------------------------------------------------------- registry


def test_registry_counters_gauges_hists():
    r = Registry()
    r.inc("c")
    r.inc("c", 2)
    r.gauge("g", 0.5)
    for v in (1.0, 2.0, 3.0, 4.0):
        r.observe("h", v)
    snap = r.snapshot()
    assert snap["counters"]["c"] == 3
    assert snap["gauges"]["g"] == 0.5
    h = snap["hists"]["h"]
    assert h["count"] == 4 and h["min"] == 1.0 and h["max"] == 4.0
    assert h["mean"] == 2.5 and h["p50"] == 3.0
    r.reset()
    assert r.snapshot() == {"counters": {}, "gauges": {}, "hists": {}}


def test_registry_inc_to_monotone():
    r = Registry()
    assert r.inc_to("c", 5) == 5
    r.inc_to("c", 3)  # never decreases
    assert r.counter("c") == 5
    r.inc_to("c", 9)
    assert r.counter("c") == 9


def test_host_value_tracer_safe():
    assert host_value(3) == 3
    assert host_value(jnp.int32(4)) == 4
    assert isinstance(host_value(jnp.int32(4)), int)
    assert host_value(np.float32(0.5)) == 0.5
    got = []

    @jax.jit
    def f(x):
        got.append(host_value(x))  # tracer: must be None, not crash
        return x + 1
    f(jnp.int32(1))
    assert got == [None]


def test_obs_count_skips_tracers(tr):
    obs.REGISTRY.reset()

    @jax.jit
    def f(x):
        obs.count("t.ctr", x)
        return x
    f(jnp.int32(5))
    assert obs.REGISTRY.counter("t.ctr") == 0
    obs.count("t.ctr", jnp.int32(5))
    assert obs.REGISTRY.counter("t.ctr") == 5


# --------------------------------------------- run_ladder round trip


@pytest.fixture(scope="module")
def ladder_fill(tmp_path_factory):
    """ONE instrumented tiny-N fill shared by the round-trip tests (the
    ladder compile is the expensive part; every test reads the same
    record + trace)."""
    from repro.sim import runner

    mp = pytest.MonkeyPatch()
    base = tmp_path_factory.mktemp("obs_fill")
    t = obs.configure(str(base / "trace.jsonl"))
    mp.setattr(runner, "CACHE_DIR", str(base / "cache"))
    before = len(runner.LADDER_PERF)
    over0 = obs.overhead_s()
    runner.run_ladder("np", members=("np", "victima_virt"),
                      workloads=("rnd", "bc"), n=128, backend="scan")
    rec = runner.LADDER_PERF[-1]
    assert len(runner.LADDER_PERF) == before + 1
    yield {"rec": rec, "tr": t, "overhead": obs.overhead_s() - over0}
    mp.undo()
    obs.configure()


def test_run_ladder_record_schema6(ladder_fill):
    rec = ladder_fill["rec"]
    assert set(rec) == set(report.SCHEMA6_FIELDS)
    assert rec["ladder"] == "np" and rec["n_members"] == 2
    assert rec["n_workloads"] == 2 and rec["sim_n"] == 128
    assert rec["cores"] == 1  # single-core fill: the degenerate lane
    assert rec["one_compile"] is True
    assert rec["trace_file"] == ladder_fill["tr"].path
    assert rec["compile_plus_sim_wall_s"] > 0
    # producer-side truth exists independently of the consumer-side wait
    assert rec["trace_gen_true_wall_s"] >= 0


def test_run_ladder_round_trip_bit_exact(ladder_fill):
    """The acceptance criterion: `report` on the JSONL reproduces the
    LADDER_PERF record exactly — including every schema-4 field."""
    tr = ladder_fill["tr"]
    tr.flush()
    events = report.read_trace(tr.path)
    offline = report.ladder_records(events, trace_file=tr.path)
    assert offline[-1] == ladder_fill["rec"]
    # and the trace carries the full span taxonomy for the fill
    by_name = {}
    for e in events:
        by_name.setdefault(e["name"], []).append(e)
    fill = by_name[names.SPAN_LADDER_FILL][-1]
    for n in (names.SPAN_TRACE_GEN, names.SPAN_CHUNK_WAIT,
              names.SPAN_DISPATCH):
        kids = [e for e in by_name[n]]
        assert kids, f"no {n} spans in trace"
    gens = [e for e in by_name[names.SPAN_TRACE_GEN]
            if e["parent"] == fill["id"]]
    assert sorted(e["attrs"]["wl"] for e in gens) == ["bc", "rnd"]


def test_run_ladder_tracer_overhead_bounded(ladder_fill):
    """Tracer overhead < 2% of the sim wall time (generous: the bound
    the ISSUE sets for the tiny-N CI fill)."""
    sim_s = ladder_fill["rec"]["compile_plus_sim_wall_s"]
    assert ladder_fill["overhead"] < 0.02 * max(sim_s, 0.05)


def test_compile_events_in_trace(ladder_fill):
    tr = ladder_fill["tr"]
    compiles = [e for e in tr.events if e["name"] == names.EV_COMPILE]
    assert compiles, "no xla_compile events captured"
    fill_id = [e for e in tr.events
               if e["name"] == names.SPAN_LADDER_FILL][-1]["id"]
    assert all(e["parent"] == fill_id for e in compiles)
    fns = {e["attrs"]["fn"] for e in compiles}
    assert "run_systems" in fns


# ------------------------------------------------- time-shard events


def test_time_shard_round_events(tr):
    from repro.sim import parallel

    def block_fn(st, blk):
        return st + jnp.sum(blk)

    trace = jnp.arange(8, dtype=jnp.int32)
    final, info = parallel.time_shard_scan(block_fn, jnp.int32(0), trace,
                                           t_shards=4)
    assert int(final) == 28
    evs = [e for e in tr.events if e["name"] == names.EV_TIME_SHARD_ROUND]
    assert len(evs) == info["rounds"]
    prefixes = [e["attrs"]["known_prefix"] for e in evs]
    assert prefixes == sorted(prefixes)  # exact prefix only grows
    assert prefixes[-1] == info["t_shards"]
    assert all(e["attrs"]["t_shards"] == info["t_shards"] for e in evs)


# ----------------------------------------------------- serve metrics


def test_engine_stats_routes_through_registry(tr):
    from repro.serve import engine

    obs.REGISTRY.reset()
    cfg = engine.EngineConfig(n_slots=4, max_blocks_per_req=8,
                              n_pool_pages=64, n_leaf_rows=32,
                              tc_sets=8, tc_ways=2, n_clusters=16)
    st = engine.init(cfg)
    for s in range(4):
        st, _ok = engine.admit(st, s, 2)
    for _ in range(6):
        st, _, _ = engine.decode_step(st, cfg)
    st = engine.retire(st, 1)
    s = engine.stats(st)
    for k in ("tc_hit_rate", "cluster_hit_rate", "walk_rate",
              "vtc_hit_rate", "pages_free", "slot_occupancy",
              "invalidate_count"):
        assert k in s, k
    assert s["vtc_hit_rate"] == s["tc_hit_rate"] + s["cluster_hit_rate"]
    assert s["slot_occupancy"] == 0.75
    assert s["invalidate_count"] >= 1  # slot 1 had live translations
    snap = obs.REGISTRY.snapshot()
    assert snap["gauges"][names.GAUGE_PAGES_FREE] == s["pages_free"]
    assert snap["counters"][names.CTR_VTC_WALK] >= 1
    h = snap["hists"][names.HIST_DECODE_STEP_S]
    assert h["count"] == 6 and h["p99"] > 0
    assert obs.REGISTRY.counter(names.CTR_DECODE_STEPS) == 6
    # repeated sampling is idempotent (inc_to, not inc)
    walks = snap["counters"][names.CTR_VTC_WALK]
    engine.stats(st)
    assert obs.REGISTRY.snapshot()["counters"][names.CTR_VTC_WALK] == walks


def test_engine_retire_countable_under_jit(tr):
    from repro.serve import engine

    cfg = engine.EngineConfig(n_slots=2, max_blocks_per_req=4,
                              n_pool_pages=32, n_leaf_rows=16,
                              tc_sets=8, tc_ways=2, n_clusters=8)
    st = engine.init(cfg)
    st, _ok = engine.admit(st, 0, 2)
    # jit-traced retire: invalidation counts are tracers; the registry
    # guard must skip (not crash), and results must match the host path
    st_jit = jax.jit(lambda s: engine.retire(s, 0))(st)
    st_host = engine.retire(st, 0)
    assert bool(jnp.all(st_jit.slot_live == st_host.slot_live))


def test_vtc_invalidation_counts_match_invalidate():
    from repro.paged import translation_cache as vtc_mod

    vtc = vtc_mod.make(8, 2, 16)
    # hand-place entries for two requests
    vtc = vtc._replace(
        tc_tags=vtc.tc_tags.at[0, 0].set((1 << 20) | 3)
                           .at[1, 1].set((2 << 20) | 4),
        tc_valid=vtc.tc_valid.at[0, 0].set(True).at[1, 1].set(True),
        cl_tags=vtc.cl_tags.at[5].set(((1 << 20) | 8) >> 3),
        cl_valid=vtc.cl_valid.at[5].set(True))
    n_tc, n_cl = vtc_mod.invalidation_counts(vtc, 1)
    assert (int(n_tc), int(n_cl)) == (1, 1)
    after = vtc_mod.invalidate_request(vtc, 1)
    assert int(jnp.sum(vtc.tc_valid)) - int(jnp.sum(after.tc_valid)) == 1
    assert int(jnp.sum(vtc.cl_valid)) - int(jnp.sum(after.cl_valid)) == 1
    s = vtc_mod.stats(vtc)
    assert s["vtc_hit_rate"] == 0.0 and 0 < s["tc_occupancy"] < 1


# ---------------------------------------------------------- CLI


def _write_bench(path, fills):
    art = {"schema": 5, "ladder_fills": fills}
    path.write_text(json.dumps(art))
    return str(path)


def test_cli_report_check_ok(ladder_fill, tmp_path, capsys):
    from repro.obs.__main__ import main

    tr = ladder_fill["tr"]
    tr.flush()
    bench = _write_bench(tmp_path / "BENCH_sweep.json", [ladder_fill["rec"]])
    rc = main(["report", tr.path, "--check", bench])
    out = capsys.readouterr().out
    assert rc == 0
    assert "check OK" in out and "bit-exact" in out


def test_cli_report_check_catches_drift(ladder_fill, tmp_path, capsys):
    from repro.obs.__main__ import main

    tr = ladder_fill["tr"]
    tr.flush()
    doctored = dict(ladder_fill["rec"], dispatch_compiles=9)
    bench = _write_bench(tmp_path / "BENCH_doctored.json", [doctored])
    rc = main(["report", tr.path, "--check", bench])
    err = capsys.readouterr().err
    assert rc == 1
    assert "dispatch_compiles" in err


def test_cli_diff_warns_on_regression(tmp_path, capsys):
    from repro.obs.__main__ import main

    base = {"ladder": "np", "sim_n": 128, "n_workloads": 2,
            "backend": "scan", "chunk": 2, "t_shards": 1,
            "trace_gen_wall_s": 0.1, "compile_plus_sim_wall_s": 10.0}
    slow = dict(base, compile_plus_sim_wall_s=15.0)  # +50%
    old = _write_bench(tmp_path / "old.json", [base])
    new = _write_bench(tmp_path / "new.json", [slow])
    rc = main(["diff", old, new, "--warn-pct", "20"])
    cap = capsys.readouterr()
    assert rc == 0  # warn-only by default: CI must not hard-fail
    assert "regression" in cap.err and "+50.0%" in cap.err
    assert main(["diff", old, new, "--warn-pct", "20", "--fail"]) == 1
    capsys.readouterr()
    # within threshold: silent
    ok = _write_bench(tmp_path / "ok.json",
                      [dict(base, compile_plus_sim_wall_s=11.0)])
    rc = main(["diff", old, ok, "--warn-pct", "20"])
    assert rc == 0 and capsys.readouterr().err == ""


# ---------------------------------------------------- sweep CLI flag


def test_sweep_parse_obs_trace():
    from repro.sim import sweep

    _, _, opts = sweep.parse_args(["--obs-trace", "/tmp/t.jsonl"])
    assert opts["obs_trace"] == "/tmp/t.jsonl"
    _, _, opts = sweep.parse_args(["--obs-trace=/tmp/t2.jsonl"])
    assert opts["obs_trace"] == "/tmp/t2.jsonl"
    with pytest.raises(SystemExit):
        sweep.parse_args(["--obs-trace"])  # missing value
    with pytest.raises(SystemExit):
        sweep.parse_args(["--obs-trace", "--tags"])  # flag as value


# --------------------------------------------------------- OB001


def test_ob001_clean_on_repo():
    from repro.analysis import obs_contract

    assert obs_contract.run() == []


def test_ob001_catches_hand_assembled_append(tmp_path):
    from repro.analysis import obs_contract

    bad = tmp_path / "runner.py"
    bad.write_text(
        "fill = obs.span(obs.names.SPAN_LADDER_FILL, ladder=l)\n"
        "LADDER_PERF.append({'ladder': l, 'wall': 1.0})\n")
    findings = obs_contract.check_runner_appends(str(bad))
    assert len(findings) == 1 and "hand-assembled" in findings[0]


def test_ob001_catches_missing_fill_attr(tmp_path):
    from repro.analysis import obs_contract

    # a runner that never sets sim_n (or any other attr source)
    bad = tmp_path / "runner.py"
    bad.write_text(
        "fill = obs.span(obs.names.SPAN_LADDER_FILL, ladder=l)\n"
        "LADDER_PERF.append(obs.report.fill_record(tr.events, fill.id))\n")
    findings = obs_contract.check_field_sources(str(bad))
    assert any("sim_n" in f for f in findings)
    assert all(f.startswith("OB001") for f in findings)


def test_ob001_in_static_passes():
    from repro import analysis

    assert "obs" in analysis.PASSES
    assert "obs" in analysis.STATIC_PASSES
