"""Hypothesis property tests on the system's core invariants."""
import pytest

hypothesis = pytest.importorskip("hypothesis")

import hypothesis.strategies as st  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from hypothesis import given, settings  # noqa: E402

from repro.core import assoc, ptwcp  # noqa: E402
from repro.core.caches import (  # noqa: E402
    BT_DATA, BT_TLB4, l2_insert, l2_lookup, l2_retag_to_tlb, make_l2)

hypothesis.settings.register_profile(
    "fast", settings(max_examples=25, deadline=None))
hypothesis.settings.load_profile("fast")


# ------------------------------------------------------------ assoc / LRU


@given(st.lists(st.integers(0, 1 << 20), min_size=1, max_size=40))
def test_lru_insert_then_lookup_hits(keys):
    a = assoc.make(4, 4)
    now = 0
    for k in keys:
        now += 1
        a, _, _ = assoc.insert_lru(a, jnp.int32(k), jnp.int32(now))
        hit, w, s = assoc.lookup(a, jnp.int32(k))
        assert bool(hit), "a just-inserted key must hit"


@given(st.lists(st.integers(0, 1 << 20), min_size=1, max_size=60))
def test_assoc_occupancy_bounded(keys):
    a = assoc.make(4, 4)
    for i, k in enumerate(keys):
        a, _, _ = assoc.insert_lru(a, jnp.int32(k), jnp.int32(i + 1))
    assert int(jnp.sum(a.valid)) <= 16


@given(st.integers(2, 6), st.integers(1, 5))
def test_lru_evicts_least_recent(n_extra, reuse_gap):
    """Filling a set beyond capacity evicts the oldest untouched key."""
    a = assoc.make(1, 4)
    # fill ways with keys 0..3 (set index is identical for multiples of 1)
    for i in range(4):
        a, _, _ = assoc.insert_lru(a, jnp.int32(i * 16), jnp.int32(i + 1))
    # touch key 0 to make key 16 the LRU
    hit, w, s = assoc.lookup(a, jnp.int32(0))
    a = assoc.touch_lru(a, s, w, jnp.int32(10))
    a, ev_tag, ev_valid = assoc.insert_lru(a, jnp.int32(99 * 16),
                                           jnp.int32(11))
    assert bool(ev_valid) and int(ev_tag) == 16


# ------------------------------------------------------------ SRRIP


@given(st.lists(st.integers(0, 3), min_size=4, max_size=4),
       st.lists(st.booleans(), min_size=4, max_size=4))
def test_srrip_victim_is_max_rrpv(rrpvs, valids):
    row = jnp.asarray(rrpvs, jnp.int32)
    val = jnp.asarray(valids)
    aged, w = assoc.srrip_age_and_pick(row, val)
    if not any(valids):
        return  # all invalid: any victim fine
    if all(valids):
        assert int(jnp.max(aged)) == assoc.RRIP_MAX
        assert int(aged[w]) == assoc.RRIP_MAX
    else:
        assert not bool(val[w]), "invalid ways must be preferred victims"


@given(st.lists(st.integers(0, 3), min_size=4, max_size=4),
       st.lists(st.booleans(), min_size=4, max_size=4))
def test_srrip_tlb_aware_reroll(rrpvs, is_tlb):
    """Under pressure, a chosen TLB victim is swapped for a non-TLB way at
    RRIP_MAX when one exists."""
    row = jnp.asarray(rrpvs, jnp.int32)
    val = jnp.ones(4, jnp.bool_)
    tlb = jnp.asarray(is_tlb)
    aged, w = assoc.srrip_victim_tlb_aware(row, val, tlb,
                                           jnp.bool_(True))
    non_tlb_at_max = ~np.asarray(tlb) & (np.asarray(aged)
                                                 >= assoc.RRIP_MAX)
    if non_tlb_at_max.any():
        assert not bool(tlb[w])


# ------------------------------------------------------------ PTW-CP


@given(st.integers(0, 7), st.integers(0, 15))
def test_ptwcp_box(freq, cost):
    pred = bool(ptwcp.predict(jnp.uint8(freq), jnp.uint8(cost)))
    expected = (1 <= cost <= 12) and (1 <= freq <= 7)
    assert pred == expected


@given(st.lists(st.tuples(st.integers(0, 1000), st.booleans()),
                min_size=1, max_size=40))
def test_ptwcp_counters_saturate(updates):
    pc = ptwcp.make_counters(8)
    for page, dram in updates:
        pc = ptwcp.update_counters(pc, jnp.int32(page % 8), dram, True)
    assert int(jnp.max(pc.freq)) <= ptwcp.FREQ_MAX
    assert int(jnp.max(pc.cost)) <= ptwcp.COST_MAX
    assert int(jnp.max(pc.cost)) <= int(jnp.max(pc.freq)) or True


# ------------------------------------------------------------ L2 TLB blocks


@given(st.lists(st.integers(0, 1 << 16), min_size=1, max_size=30))
def test_l2_tlb_block_typed_tags(keys):
    """A TLB block never aliases a data block with the same tag bits."""
    l2 = make_l2(4, 4)
    for i, k in enumerate(keys):
        l2 = l2_retag_to_tlb(l2, jnp.int32(k), BT_TLB4, jnp.bool_(True),
                             True, True)
        hit_t, _, _ = l2_lookup(l2, jnp.int32(k), BT_TLB4)
        hit_d, _, _ = l2_lookup(l2, jnp.int32(k), BT_DATA)
        assert bool(hit_t) and not bool(hit_d)


@given(st.lists(st.integers(0, 1 << 16), min_size=1, max_size=30))
def test_l2_live_counts_match(keys):
    """n_tlb4 always equals the actual number of live TLB blocks."""
    l2 = make_l2(4, 4)
    for i, k in enumerate(keys):
        if i % 3 == 2:
            l2 = l2_insert(l2, jnp.int32(k), BT_DATA, jnp.bool_(False),
                           True, True)
        else:
            l2 = l2_retag_to_tlb(l2, jnp.int32(k), BT_TLB4,
                                 jnp.bool_(True), True, True)
        actual = int(jnp.sum(l2.valid & (l2.btype == BT_TLB4)))
        assert actual == int(l2.n_tlb4)


def test_retag_idempotent():
    """Re-inserting an existing TLB region must not duplicate it."""
    l2 = make_l2(4, 4)
    for _ in range(5):
        l2 = l2_retag_to_tlb(l2, jnp.int32(42), BT_TLB4, jnp.bool_(True),
                             True, True)
    assert int(l2.n_tlb4) == 1
