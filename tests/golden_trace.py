"""Shared fixture data for the golden-snapshot tests.

The trace and config here pin the pre-refactor MMU behaviour: the stage
pipeline must reproduce these Stats bit-for-bit (see
tests/golden/mmu_stats.json, regenerated via
``PYTHONPATH=src:tests python -m golden_regen``).
"""
import numpy as np

from repro.core.mmu import SimConfig

GOLDEN_SEED = 1234
GOLDEN_N = 6000

# tiny structures so each system compiles in seconds, yet every flow
# (evictions, background walks, 2M pages, pressure) is exercised
GOLDEN_CFG = SimConfig(
    l2tlb_sets=4, l2tlb_ways=4,
    l1d4_sets=2, l1d4_ways=2, l1d2_sets=2, l1d2_ways=2,
    l2_sets=64, l2_ways=8, l3_sets=64, l3_ways=8,
    n_pages4=1 << 12, n_pages2=1 << 8, n_pagesh=1 << 8, n_feat=1,
)

GOLDEN_SYSTEMS = {
    "radix": {},
    "victima": {"victima": True},
}


def golden_trace(n: int = GOLDEN_N, seed: int = GOLDEN_SEED) -> dict:
    """Deterministic mixed trace: half cyclic sweep (TLB-thrashing but
    Victima-friendly), half random, 25% 2M-backed accesses."""
    rng = np.random.default_rng(seed)
    base = rng.integers(0, 4096, size=n)
    cyc = np.tile(np.arange(512), n // 512 + 1)[:n]
    pages = np.where(rng.random(n) < 0.5, cyc, base).astype(np.int32)
    return {
        "vpn": pages,
        "is2m": rng.random(n) < 0.25,
        "line": (pages * 64 + rng.integers(0, 64, size=n)).astype(np.int32),
        "ipa": np.full((n,), 3.0, np.float32),
    }


def stats_to_jsonable(stats) -> dict:
    out = {}
    for name, v in stats._asdict().items():
        a = np.asarray(v)
        if a.ndim == 0:
            out[name] = a.item()
        else:
            out[name] = a.tolist()
    return out
